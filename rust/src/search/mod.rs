//! Parallel plan-search engine — the layer the paper's decoupling exists to
//! enable (and what FlexFlow-style systems show unlocks the speedups):
//! instead of hand-picking an sProgram, enumerate the feasible [`PlanSpec`]
//! grid for a model + cluster, prune candidates that cannot work
//! (degree/divisibility mismatches, static-memory lower bounds above device
//! capacity — via the [`crate::cost`] model), then run the full
//! transform → schedule-validate → materialize → simulate pipeline for every
//! survivor in parallel on [`crate::util::pool`] worker threads and rank the
//! results by iteration time.
//!
//! # Zero-rebuild evaluation
//!
//! The whole search runs off **one** borrowed probe model: [`search`]
//! takes `&Model`, shares it read-only across the worker threads, and
//! every candidate build clones only the graph inside
//! [`Planner::build`](crate::plans::Planner::build) — nothing in the
//! per-candidate path (or the DES re-rank) ever reconstructs the model
//! from its builder. The `--fidelity des` re-rank is zero-rebuild too:
//! evaluation keeps the `(Graph, TaskGraph, Plan)` artifacts of the
//! current top [`SearchConfig::des_top`] list-ranked candidates in a
//! bounded cache (memory stays O(des_top), not O(grid) — worse-ranked
//! artifacts are evicted as better ones arrive) and feeds them straight
//! to [`des::execute`], so the transform → validate → materialize
//! pipeline runs exactly once per evaluated candidate.
//!
//! # Three-level search over replicated heterogeneous pipelines
//!
//! The engine (here) enumerates every registered planner's candidates —
//! including the `hetero` planner, whose
//! [`StageSpec`](crate::plans::StageSpec) lists give pipelines per-stage
//! intra-stage transformations. The hetero grid itself is **three-level**
//! (all inside the planner's `candidates()`): an outer *dp* loop composes
//! replicated copies of a pipeline over `n / dp` devices (gradients
//! RVD-synchronized across the replicas every iteration), a middle loop
//! enumerates stage-width compositions per pipeline depth, and an inner
//! choice picks each stage's transformation by analytic cost-model
//! ranking — so only the best-ranked combinations of an
//! otherwise-combinatorial space reach the engine. [`SearchConfig::dp_min`]
//! restricts the whole grid to replicated plans (the CI dp-smoke runs with
//! `--dp-min 2`).
//!
//! # Dominance pruning
//!
//! The finer grid is affordable because candidates are *dominance-pruned*
//! before simulation: every spec gets a sound analytic lower bound on its
//! iteration time ([`Cluster::plan_time_lower_bound`] — mean-share compute
//! at saturation ceiling + ring α–β gradient sync). Candidates are sorted
//! by bound, a fixed-size seed prefix is simulated, and any remaining spec
//! whose *lower bound* already exceeds the best *simulated* seed time is
//! skipped — it provably cannot win. The decision uses only the seed
//! results, so searches stay deterministic, and pruned counts are reported
//! in the [`SearchReport`] (never silently dropped). Disable with
//! [`SearchConfig::prune`] = false; the prune-on/prune-off agreement is
//! covered by `rust/tests/hetero_search.rs`.
//!
//! # Fidelity tiers
//!
//! Scoring is tiered by cost: (1) the analytic lower bound above prunes,
//! (2) the list simulator ([`crate::sim`]) screens every survivor, and
//! (3) with [`SearchConfig::fidelity`] = [`Fidelity::Des`] the
//! discrete-event engine ([`crate::des`]) re-scores the top
//! [`SearchConfig::des_top`] list-ranked candidates — crediting
//! comm/compute overlap and charging link contention — and the head of the
//! ranking is re-ordered by the DES score. Both scores are kept in
//! [`Metrics`] (`makespan` = list, `des_makespan` = DES), so the overlap
//! headroom the cheaper tier missed is auditable per candidate.
//!
//! # MCMC refinement with optimality-gap certificates
//!
//! With [`SearchConfig::refine`] set, a fourth tier runs after the DES
//! re-rank: each of the top-k grid candidates seeds a deterministic
//! Metropolis chain over plan mutations — stage-boundary moves (biased by
//! the RVD conversion cost of the new cut,
//! [`crate::rvd::stage_conversion_time`]), recompute/offload toggles on
//! one stage, widen/narrow of one stage, micro-batch rescaling, and
//! adjacent-op swaps in one device's serial order — accepted or rejected
//! on DES makespan via incremental delta replay
//! ([`crate::des::delta::BaseRun`]), which re-executes only the event
//! suffix a mutation perturbs. Every refined candidate carries a `gap`
//! certificate: its DES makespan relative to the analytic lower bound
//! [`Cluster::plan_time_lower_bound`], so "best found" comes with
//! "provably within X% of optimal". See [`refine`] for the loop.
//!
//! # The fourth axis: schedules as data
//!
//! Pipeline schedules are [`SchedSpec`] values carried in
//! [`PlanSpec::sched`] (the `sched{...}` label token), so the temporal
//! discipline is searched alongside dp × pp × tp instead of being a
//! planner constant: the megatron grid contributes each pipelined point
//! under 1F1B *and* zero-bubble, [`feasibility`] gates tokens against the
//! plan family (hetero is 1F1B-only, 3F1B's recycling passes are outside
//! the slot vocabulary) and structurally checks the resolved rows, and
//! [`SearchConfig::schedule`] pins the whole grid to one schedule
//! (incompatible candidates count as excluded, duplicates collapse). The
//! refinement tier mutates along this axis too — see
//! [`refine::mutate_schedule`] — and an accepted permutation survives in
//! the winner's spec label, re-materializable from the label alone.
//!
//! Entry points: [`search`] (used by `superscaler search` and
//! `examples/plan_explorer.rs`), [`enumerate`] + [`feasibility`] for callers
//! that want the grid without evaluating it.

pub mod refine;

pub use refine::{RefineConfig, RefineSummary};

use crate::cost::{Cluster, ModelStats};
use crate::des;
use crate::graph::Graph;
use crate::materialize::{self, CommMode, Plan};
use crate::models::Model;
use crate::plans::{registry, PlanKind, PlanOutput, PlanSpec, Planner};
use crate::schedule::{self, SchedName, SchedSpec};
use crate::sim;
use crate::util::pool;
use crate::util::table::Table;
use crate::util::{fmt_bytes, fmt_secs};
use std::sync::Mutex;

/// Which execution model scores (and finally ranks) the candidates.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Fidelity {
    /// List simulation only (tier 2) — fast, overlap-blind.
    List,
    /// List screening plus a discrete-event re-rank of the top candidates
    /// (tier 3) — credits comm/compute overlap and link contention.
    Des,
}

impl Fidelity {
    /// Parse a `--fidelity` flag value — the one parse the CLI and the
    /// examples share, so error behavior cannot drift between front-ends.
    pub fn parse(s: &str) -> Option<Fidelity> {
        match s {
            "list" => Some(Fidelity::List),
            "des" => Some(Fidelity::Des),
            _ => None,
        }
    }
}

/// Knobs for one search run.
#[derive(Clone, Debug)]
pub struct SearchConfig {
    /// Worker threads evaluating candidates; 0 = one per available CPU.
    pub workers: usize,
    /// Communication tier used for every candidate's materialization.
    pub comm: CommMode,
    /// Hard cap on evaluated candidates (0 = unlimited). Overflow is
    /// reported as [`SearchReport::capped`], never silently dropped; the
    /// cap keeps the *best-bounded* candidates.
    pub max_candidates: usize,
    /// Include the heterogeneous per-stage pipeline space (`hetero`).
    pub hetero: bool,
    /// Only consider specs with at least this data-parallel degree
    /// (1 = unrestricted). Filtered specs count toward
    /// [`SearchReport::excluded`] — dropped by configuration, not
    /// infeasibility, and never silently.
    pub dp_min: usize,
    /// Dominance-prune candidates whose analytic lower bound exceeds the
    /// best simulated seed candidate (sound: can never drop the optimum).
    pub prune: bool,
    /// Final scoring fidelity (see [`Fidelity`]).
    pub fidelity: Fidelity,
    /// How many top list-ranked candidates the DES re-scores when
    /// `fidelity` is [`Fidelity::Des`].
    pub des_top: usize,
    /// Run the MCMC refinement tier over the top grid candidates
    /// (`None` = grid search only). See [`refine`].
    pub refine: Option<RefineConfig>,
    /// Pin every candidate to one pipeline schedule (the fourth search
    /// axis): each grid spec is re-labeled with this `sched{...}` token,
    /// schedule-incompatible candidates are dropped (counted in
    /// [`SearchReport::excluded`]) and duplicates collapse. `None` lets
    /// every planner contribute its own schedule points.
    pub schedule: Option<SchedSpec>,
    /// Score the ranking head's resilience under seeded faults
    /// (`--faults` / `--mtbf`): each top candidate is re-run under the
    /// fault trace with checkpoint/restart modeled, [`Metrics`] gains
    /// goodput/recovery columns, and the head re-sorts by
    /// goodput-adjusted iteration time. With
    /// [`crate::fault::ResilienceConfig::spread`] set, dp replicas are
    /// re-placed rack-by-rack before evaluation so a rack loss fells one
    /// replica instead of all of them. `None` (the default) leaves the
    /// search byte-identical to a fault-unaware run.
    pub resilience: Option<crate::fault::ResilienceConfig>,
}

impl Default for SearchConfig {
    fn default() -> Self {
        SearchConfig {
            workers: 0,
            comm: CommMode::InterRvd,
            max_candidates: 256,
            hetero: true,
            dp_min: 1,
            prune: true,
            fidelity: Fidelity::List,
            des_top: 8,
            refine: None,
            schedule: None,
            resilience: None,
        }
    }
}

impl SearchConfig {
    /// Start a [`SearchConfigBuilder`] from the defaults — the supported
    /// way to construct a config (field-by-field struct literals break
    /// every time the search grows an axis; the builder defaults every
    /// knob and call sites set only what they mean).
    pub fn builder() -> SearchConfigBuilder {
        SearchConfigBuilder::default()
    }
}

/// Fluent constructor for [`SearchConfig`]; see [`SearchConfig::builder`].
#[derive(Clone, Debug, Default)]
pub struct SearchConfigBuilder {
    cfg: SearchConfig,
}

impl SearchConfigBuilder {
    /// See [`SearchConfig::workers`].
    pub fn workers(mut self, workers: usize) -> Self {
        self.cfg.workers = workers;
        self
    }

    /// See [`SearchConfig::comm`].
    pub fn comm(mut self, comm: CommMode) -> Self {
        self.cfg.comm = comm;
        self
    }

    /// See [`SearchConfig::max_candidates`].
    pub fn max_candidates(mut self, cap: usize) -> Self {
        self.cfg.max_candidates = cap;
        self
    }

    /// See [`SearchConfig::hetero`].
    pub fn hetero(mut self, hetero: bool) -> Self {
        self.cfg.hetero = hetero;
        self
    }

    /// See [`SearchConfig::dp_min`].
    pub fn dp_min(mut self, dp_min: usize) -> Self {
        self.cfg.dp_min = dp_min;
        self
    }

    /// See [`SearchConfig::prune`].
    pub fn prune(mut self, prune: bool) -> Self {
        self.cfg.prune = prune;
        self
    }

    /// See [`SearchConfig::fidelity`].
    pub fn fidelity(mut self, fidelity: Fidelity) -> Self {
        self.cfg.fidelity = fidelity;
        self
    }

    /// See [`SearchConfig::des_top`].
    pub fn des_top(mut self, des_top: usize) -> Self {
        self.cfg.des_top = des_top;
        self
    }

    /// See [`SearchConfig::refine`].
    pub fn refine(mut self, refine: Option<RefineConfig>) -> Self {
        self.cfg.refine = refine;
        self
    }

    /// See [`SearchConfig::schedule`].
    pub fn schedule(mut self, schedule: Option<SchedSpec>) -> Self {
        self.cfg.schedule = schedule;
        self
    }

    /// See [`SearchConfig::resilience`].
    pub fn resilience(mut self, resilience: Option<crate::fault::ResilienceConfig>) -> Self {
        self.cfg.resilience = resilience;
        self
    }

    pub fn build(self) -> SearchConfig {
        self.cfg
    }
}

/// Candidates simulated up-front (in lower-bound order) to establish the
/// dominance-pruning threshold. Fixed so searches are deterministic
/// regardless of worker count.
const PRUNE_SEED: usize = 8;

/// Why a candidate spec was pruned before evaluation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Infeasible {
    /// `spec.devices()` does not match the cluster's GPU count.
    DeviceMismatch { want: usize, got: usize },
    /// More data-parallel replicas than global-batch samples.
    BatchTooSmall { batch: usize, dp: usize },
    /// More pipeline stages than the model has layers.
    TooManyStages { stages: usize, layers: usize },
    /// Static-memory lower bound exceeds device capacity.
    MemoryBound { need: u64, cap: u64 },
    /// Micro-batch split finer than the per-replica batch.
    MicroTooFine { batch: usize, dp: usize, micro: usize },
    /// A hetero stage combines mutually exclusive transformations
    /// (co-shard is single-device, so `tp > 1` excludes `shards > 1`).
    StageConflict { stage: usize, tp: usize, shards: usize },
    /// A hetero spec whose `pp` disagrees with its stage-list length.
    StageArity { pp: usize, stages: usize },
    /// A hetero spec's explicit per-stage layer counts are incomplete or
    /// do not sum to the model's layer count.
    StageLayerSplit { assigned: usize, layers: usize },
    /// The spec carries a `sched{...}` token its plan family cannot honor,
    /// or the resolved schedule rows are structurally unsound for the
    /// spec's (pp, micro) shape.
    ScheduleUnsupported { kind: PlanKind, why: String },
}

impl std::fmt::Display for Infeasible {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Infeasible::DeviceMismatch { want, got } => {
                write!(f, "spec occupies {got} devices, cluster has {want}")
            }
            Infeasible::BatchTooSmall { batch, dp } => {
                write!(f, "dp {dp} exceeds global batch {batch}")
            }
            Infeasible::TooManyStages { stages, layers } => {
                write!(f, "{stages} stages over {layers} layers")
            }
            Infeasible::MemoryBound { need, cap } => {
                write!(f, "needs >= {} static bytes, device holds {}", need, cap)
            }
            Infeasible::MicroTooFine { batch, dp, micro } => {
                write!(f, "dp {dp} x micro {micro} exceeds global batch {batch}")
            }
            Infeasible::StageConflict { stage, tp, shards } => {
                write!(f, "stage {stage}: tp {tp} excludes shards {shards}")
            }
            Infeasible::StageArity { pp, stages } => {
                write!(f, "pp {pp} disagrees with {stages} stage specs")
            }
            Infeasible::StageLayerSplit { assigned, layers } => {
                write!(f, "stage layer split assigns {assigned} layers, model has {layers}")
            }
            Infeasible::ScheduleUnsupported { kind, why } => {
                write!(f, "schedule unsupported for {kind:?}: {why}")
            }
        }
    }
}

/// Schedule-axis compatibility (see the module doc): which plan families
/// can honor a `sched{...}` token, and whether the resolved rows are
/// structurally sound for the spec's (pp, micro) shape. Run as part of
/// [`feasibility`] so an incompatible (family, schedule) pair is pruned
/// before any graph work.
fn sched_feasibility(spec: &PlanSpec, sched: &SchedSpec) -> Result<(), Infeasible> {
    let kind = spec.kind;
    let reject = |why: &str| Err(Infeasible::ScheduleUnsupported { kind, why: why.to_string() });
    let wgrad_ok = match kind {
        // The megatron family splits backwards for W slots.
        PlanKind::Megatron | PlanKind::GPipe | PlanKind::Tp => true,
        // Interlaced lowers W-free rows only (embedding backward unsplit).
        PlanKind::Interlaced => false,
        // Hetero pipelines hard-code 1F1B ordering per stage.
        PlanKind::Hetero => {
            if *sched != SchedSpec::Named(SchedName::OneFOneB) {
                return reject("hetero pipelines support only the 1f1b schedule");
            }
            false
        }
        // Everything else (dp, 3F1B's recycling passes, ...) has no
        // (micro × F/B/W) pipeline the slot vocabulary can describe.
        _ => return reject("plan family has no schedulable pipeline"),
    };
    let (pp, k) = (spec.pp.max(1), spec.micro.max(1));
    let rows = sched.resolve(pp, k);
    if rows.rows.len() != pp {
        return reject("schedule row arity disagrees with pipeline depth");
    }
    if rows.uses_wgrad() && !wgrad_ok {
        return reject("W slots unsupported for this plan family");
    }
    if let Err(e) = rows.check(k) {
        return reject(&e.to_string());
    }
    Ok(())
}

/// Cheap feasibility check run before any graph transformation: degree
/// consistency, batch divisibility headroom, stage/layer fit and the
/// cost-model memory bound.
pub fn feasibility(spec: &PlanSpec, model: &Model, cluster: &Cluster) -> Result<(), Infeasible> {
    let want = cluster.num_gpus();
    let got = spec.devices();
    if got != want {
        return Err(Infeasible::DeviceMismatch { want, got });
    }
    let batch = model.global_batch.max(1);
    if spec.dp > batch {
        return Err(Infeasible::BatchTooSmall { batch, dp: spec.dp });
    }
    if spec.dp.max(1) * spec.micro.max(1) > batch {
        return Err(Infeasible::MicroTooFine { batch, dp: spec.dp.max(1), micro: spec.micro });
    }
    let layers = model.layers.len().max(1);
    if spec.pp > layers {
        return Err(Infeasible::TooManyStages { stages: spec.pp, layers });
    }
    if let Some(stages) = &spec.stages {
        if spec.pp != stages.len() {
            return Err(Infeasible::StageArity { pp: spec.pp, stages: stages.len() });
        }
        for (i, st) in stages.iter().enumerate() {
            if st.tp.max(1) > 1 && st.shards.max(1) > 1 {
                return Err(Infeasible::StageConflict { stage: i, tp: st.tp, shards: st.shards });
            }
        }
        // Explicit layer counts are all-or-nothing and must tile the model
        // exactly (a partial split would silently fall back to balanced).
        let with_layers = stages.iter().filter(|s| s.layers > 0).count();
        if with_layers > 0 {
            let assigned: usize = stages.iter().map(|s| s.layers).sum();
            if with_layers != stages.len() || assigned != layers {
                return Err(Infeasible::StageLayerSplit { assigned, layers });
            }
        }
    }
    if let Some(sched) = &spec.sched {
        sched_feasibility(spec, sched)?;
    }
    // Optimistic capacity: on mixed fleets a plan is provably infeasible
    // only if even the largest device kind cannot hold its static share.
    let need = spec.static_bytes_lower_bound(model.graph.weight_bytes());
    let cap = cluster.max_mem_bytes();
    if need > cap {
        return Err(Infeasible::MemoryBound { need, cap });
    }
    Ok(())
}

/// Enumerate the feasible `(planner, spec)` grid for `model` on `cluster`.
/// Returns the surviving candidates and how many were pruned.
pub fn enumerate(
    model: &Model,
    cluster: &Cluster,
) -> (Vec<(&'static dyn Planner, PlanSpec)>, usize) {
    enumerate_filtered(model, cluster, true)
}

/// [`enumerate`] with the heterogeneous per-stage space optionally
/// excluded (the `search --hetero` gate).
pub fn enumerate_filtered(
    model: &Model,
    cluster: &Cluster,
    hetero: bool,
) -> (Vec<(&'static dyn Planner, PlanSpec)>, usize) {
    let (out, pruned, _) = enumerate_constrained(model, cluster, hetero, 1);
    (out, pruned)
}

/// [`enumerate_filtered`] additionally restricted to specs with
/// `spec.dp >= dp_min` (the `search --dp-min` gate — e.g. the CI dp-smoke
/// run explores only replicated plans). Returns
/// `(candidates, infeasible, excluded)` — config exclusions are counted
/// separately from infeasibility so the coverage accounting stays honest.
pub fn enumerate_constrained(
    model: &Model,
    cluster: &Cluster,
    hetero: bool,
    dp_min: usize,
) -> (Vec<(&'static dyn Planner, PlanSpec)>, usize, usize) {
    let mut out = Vec::new();
    let mut pruned = 0;
    let mut excluded = 0;
    for &p in registry::all() {
        if !p.applicable(model) {
            continue;
        }
        if !hetero && p.kind() == crate::plans::PlanKind::Hetero {
            continue;
        }
        for spec in p.candidates(model, cluster) {
            if spec.dp.max(1) < dp_min {
                excluded += 1;
                continue;
            }
            match feasibility(&spec, model, cluster) {
                Ok(()) => out.push((p, spec)),
                Err(_) => pruned += 1,
            }
        }
    }
    (out, pruned, excluded)
}

/// Simulation metrics of one evaluated candidate.
#[derive(Clone, Debug)]
pub struct Metrics {
    /// Iteration time under the list simulator, seconds.
    pub makespan: f64,
    /// Iteration time under the discrete-event engine, seconds — `Some`
    /// only for the top candidates a `--fidelity des` search re-scored.
    /// `makespan - des_makespan` is the overlap/contention headroom the
    /// list model could not see.
    pub des_makespan: Option<f64>,
    /// Whether the DES timeline exceeded device memory. Overlap raises
    /// concurrent activation liveness, so a plan can fit under the list
    /// schedule yet OOM under the DES one; such candidates sort to the
    /// back of the re-scored head and are flagged in the report status
    /// (the list-tier `oom`/ranking stays untouched so the CI gate's
    /// measurement remains fidelity-independent).
    pub des_oom: bool,
    pub aggregate_tflops: f64,
    pub comm_bytes: u64,
    /// Max per-device peak memory, bytes.
    pub peak_mem: u64,
    /// Mean bubble fraction of the iteration.
    pub bubble_frac: f64,
    pub oom: bool,
    /// Optimality-gap certificate vs [`Cluster::plan_time_lower_bound`]:
    /// `des_makespan / lower_bound - 1`, clamped at 0. `Some` only for
    /// candidates the refinement tier scored.
    pub gap: Option<f64>,
    /// Useful-work fraction under the configured fault trace (fault-free
    /// makespan / faulted makespan, ≤ 1). `Some` only for candidates the
    /// resilience tier scored ([`SearchConfig::resilience`]).
    pub goodput: Option<f64>,
    /// Worst single outage-to-recovered window under the trace, seconds
    /// (repair + checkpoint reload + replay). `Some` with `goodput`.
    pub recovery: Option<f64>,
}

/// What happened to one candidate.
#[derive(Clone, Debug)]
pub enum Outcome {
    Ok(Metrics),
    /// Plan construction (transformation) failed.
    BuildError(String),
    /// Schedule validation found a deadlock / missing producer.
    ScheduleError(String),
    /// The evaluation pipeline panicked; the payload is the panic message.
    /// Caught per candidate ([`std::panic::catch_unwind`]) so one buggy
    /// planner yields a typed error row instead of poisoning the pool and
    /// killing the whole search.
    Panicked(String),
}

/// One evaluated point of the search grid.
#[derive(Clone, Debug)]
pub struct Candidate {
    /// Registry name of the planner that built it.
    pub planner: &'static str,
    pub spec: PlanSpec,
    /// The built plan's self-reported name (empty if construction failed).
    pub plan_name: String,
    pub outcome: Outcome,
}

impl Candidate {
    /// 0 = valid, 1 = valid but OOM, 2 = failed. Primary ranking key.
    fn rank_class(&self) -> u8 {
        match &self.outcome {
            Outcome::Ok(m) if !m.oom => 0,
            Outcome::Ok(_) => 1,
            _ => 2,
        }
    }

    pub fn metrics(&self) -> Option<&Metrics> {
        match &self.outcome {
            Outcome::Ok(m) => Some(m),
            _ => None,
        }
    }
}

/// The ranked result of one search run.
#[derive(Debug)]
pub struct SearchReport {
    pub model: String,
    pub gpus: usize,
    /// Fabric the cluster was modeled on (`flat`, `fat-tree:K`, `rail:R`).
    pub topology: String,
    /// All evaluated candidates: valid non-OOM by iteration time, then OOM,
    /// then failures. Deterministic for identical inputs.
    pub ranked: Vec<Candidate>,
    /// Candidates rejected by the feasibility checks before evaluation.
    pub pruned: usize,
    /// Feasible-or-not candidates dropped by configuration
    /// ([`SearchConfig::dp_min`]) before the feasibility checks — reported
    /// apart from `pruned` so "infeasible" keeps meaning infeasible.
    pub excluded: usize,
    /// Feasible candidates dropped by the [`SearchConfig::max_candidates`]
    /// cap (the worst-bounded ones).
    pub capped: usize,
    /// Feasible candidates skipped by dominance pruning: their analytic
    /// lower bound already exceeded the best simulated seed candidate.
    pub pruned_bound: usize,
    /// Candidates actually built + simulated.
    pub evaluated: usize,
    /// Scoring fidelity the ranking was produced under.
    pub fidelity: Fidelity,
    /// Candidates re-scored by the discrete-event engine (0 under
    /// [`Fidelity::List`]).
    pub des_rescored: usize,
    /// Candidates refined by the MCMC tier (0 without
    /// [`SearchConfig::refine`]).
    pub refined: usize,
    /// Aggregate refinement accounting (`None` without the refine tier).
    pub refine: Option<RefineSummary>,
    /// Candidates the resilience tier re-ran under the fault trace (0
    /// without [`SearchConfig::resilience`]).
    pub resilience_scored: usize,
    /// Resilience breakdown of the winning candidate (`None` without the
    /// resilience tier, or when no valid candidate survived it).
    pub resilience: Option<crate::fault::ResilienceReport>,
    /// Wall-clock search time, seconds.
    pub wall_secs: f64,
}

impl SearchReport {
    /// Best valid (non-OOM) plan, if any — under the report's fidelity
    /// (DES order when the head was re-scored).
    pub fn best(&self) -> Option<&Candidate> {
        self.ranked.first().filter(|c| c.rank_class() == 0)
    }

    /// The valid (non-OOM) candidate with the smallest *list-simulated*
    /// iteration time — fidelity-independent (a `--fidelity des` re-rank
    /// reorders the head of `ranked` but cannot change this winner), so
    /// the CI perf baseline records a consistent (plan, makespan) pair.
    pub fn best_by_list(&self) -> Option<&Candidate> {
        self.ranked
            .iter()
            .filter(|c| c.rank_class() == 0)
            .min_by(|a, b| {
                let (ta, tb) = (a.metrics().unwrap().makespan, b.metrics().unwrap().makespan);
                ta.partial_cmp(&tb)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then_with(|| a.plan_name.cmp(&b.plan_name))
            })
    }

    /// Minimum *list-simulated* iteration time over valid candidates —
    /// what the CI perf baseline gates on.
    pub fn best_list_makespan(&self) -> Option<f64> {
        self.best_by_list().and_then(|c| c.metrics()).map(|m| m.makespan)
    }

    /// Total specs the grid produced, however they were dispatched.
    pub fn total_candidates(&self) -> usize {
        self.evaluated + self.pruned + self.excluded + self.capped + self.pruned_bound
    }

    /// Render the top `top` rows (0 = all) as a console/CSV table. The
    /// title carries the full simulated/pruned accounting so search
    /// coverage is auditable from the table alone.
    pub fn to_table(&self, top: usize) -> Table {
        let mut t = Table::new(
            &format!(
                "plan search: {} on {} GPUs — {} specs simulated, {} infeasible, \
                 {} dp-excluded, {} capped, {} cost-dominated, {} des-rescored, \
                 {} refined, {}",
                self.model,
                self.gpus,
                self.evaluated,
                self.pruned,
                self.excluded,
                self.capped,
                self.pruned_bound,
                self.des_rescored,
                self.refined,
                fmt_secs(self.wall_secs)
            ),
            &[
                "#", "plan", "spec", "iteration", "DES", "TFLOPS", "comm", "peak mem", "bubble%",
                "gap", "goodput", "recover", "status",
            ],
        );
        let n = if top == 0 { self.ranked.len() } else { top };
        // Failed rows share one shape (nine dash columns + a status); build
        // each row's strings once instead of per-arm duplicates.
        let err_row = |t: &mut Table, rank: String, c: &Candidate, status: String| {
            let mut row = vec![rank, c.planner.to_string(), c.spec.label()];
            row.extend(std::iter::repeat_with(|| "-".to_string()).take(9));
            row.push(status);
            t.row(row);
        };
        for (i, c) in self.ranked.iter().take(n).enumerate() {
            let rank = (i + 1).to_string();
            match &c.outcome {
                Outcome::Ok(m) => t.row([
                    rank,
                    c.planner.to_string(),
                    c.spec.label(),
                    fmt_secs(m.makespan),
                    m.des_makespan.map(fmt_secs).unwrap_or_else(|| "-".to_string()),
                    format!("{:.1}", m.aggregate_tflops),
                    fmt_bytes(m.comm_bytes),
                    fmt_bytes(m.peak_mem),
                    format!("{:.0}%", 100.0 * m.bubble_frac),
                    m.gap.map(|g| format!("{:.1}%", 100.0 * g)).unwrap_or_else(|| "-".to_string()),
                    m.goodput
                        .map(|g| format!("{:.0}%", 100.0 * g))
                        .unwrap_or_else(|| "-".to_string()),
                    m.recovery.map(fmt_secs).unwrap_or_else(|| "-".to_string()),
                    if m.oom {
                        "OOM".to_string()
                    } else if m.des_oom {
                        "DES-OOM".to_string()
                    } else {
                        "ok".to_string()
                    },
                ]),
                Outcome::BuildError(e) => err_row(&mut t, rank, c, format!("invalid: {e}")),
                Outcome::ScheduleError(e) => err_row(&mut t, rank, c, format!("deadlock: {e}")),
                Outcome::Panicked(e) => err_row(&mut t, rank, c, format!("panicked: {e}")),
            }
        }
        t
    }
}

/// Evaluation artifacts kept for the DES re-rank: the transformed graph,
/// the prepared task graph (serial hints included) and the materialized
/// plan — exactly what [`des::execute`] consumes, so a re-score replays
/// the candidate without re-running transform → validate → materialize.
struct DesArtifacts {
    graph: Graph,
    tg: sim::TaskGraph,
    plan: Plan,
}

/// Bounded best-k artifact cache, keyed by candidate identity and ordered
/// by list makespan — the same primary key the ranking's class-0 head
/// sorts by, so after evaluation it holds the artifacts of (up to) the
/// `des_top` candidates the DES will re-score. Offers are made under a
/// mutex from the worker threads; the final contents are the k smallest
/// `(makespan, key)` pairs regardless of arrival order, which keeps
/// `--fidelity des` searches deterministic under any worker count.
struct ArtifactCache {
    cap: usize,
    inner: Mutex<Vec<(u64, String, DesArtifacts)>>,
}

impl ArtifactCache {
    fn new(cap: usize) -> ArtifactCache {
        ArtifactCache { cap: cap.max(1), inner: Mutex::new(Vec::new()) }
    }

    /// Keep `art` iff it ranks within the best `cap` offers so far; the
    /// worst-ranked cached entry is evicted (memory stays O(cap)).
    fn offer(&self, makespan: f64, key: String, art: DesArtifacts) {
        let bits = makespan.to_bits(); // makespans are >= 0: bit order = numeric order
        let mut v = self.inner.lock().unwrap();
        if v.len() >= self.cap {
            match v.last() {
                Some(last) if (bits, key.as_str()) >= (last.0, last.1.as_str()) => return,
                _ => {}
            }
        }
        let pos = v.partition_point(|e| (e.0, e.1.as_str()) <= (bits, key.as_str()));
        v.insert(pos, (bits, key, art));
        v.truncate(self.cap);
    }

    fn take(&self, key: &str) -> Option<DesArtifacts> {
        let mut v = self.inner.lock().unwrap();
        let i = v.iter().position(|e| e.1 == key)?;
        Some(v.remove(i).2)
    }
}

/// Cache/identity key of one candidate: planner name + complete spec label.
fn cand_key(planner: &str, spec: &PlanSpec) -> String {
    format!("{planner}|{}", spec.label())
}

/// Re-order a DES-scored head slice: DES-OOM plans last, then by DES time;
/// entries without a DES score fall back to their list makespan so they
/// keep the list ranking rather than drifting alphabetically. Shared by
/// the `--fidelity des` re-rank and the refinement tier (which rewrites
/// `des_makespan` with each chain's best).
fn sort_des_head(head: &mut [Candidate]) {
    head.sort_by(|a, b| {
        let key = |c: &Candidate| {
            let m = c.metrics();
            (
                m.map(|m| m.des_oom).unwrap_or(true),
                m.and_then(|m| m.des_makespan).unwrap_or(f64::INFINITY),
                m.map(|m| m.makespan).unwrap_or(f64::INFINITY),
            )
        };
        let (ka, kb) = (key(a), key(b));
        ka.0.cmp(&kb.0)
            .then_with(|| ka.1.partial_cmp(&kb.1).unwrap_or(std::cmp::Ordering::Equal))
            .then_with(|| ka.2.partial_cmp(&kb.2).unwrap_or(std::cmp::Ordering::Equal))
            .then_with(|| a.plan_name.cmp(&b.plan_name))
    });
}

/// Fault-domain-aware placement pass: when the candidate's contiguous dp
/// replicas straddle rack boundaries and a rack-aligned re-placement
/// exists, remap the schedule so each replica sits inside one rack — a
/// rack loss then fells one replica instead of several. No-op (and
/// bitwise neutral) when spreading cannot help; see
/// [`crate::fault::placement::rack_spread_map`].
fn apply_rack_spread(schedule: &mut schedule::Schedule, spec: &PlanSpec, cluster: &Cluster) {
    if let Some(map) = crate::fault::placement::rack_spread_map(spec.dp.max(1), cluster) {
        schedule.remap_devices(|d| map[d]);
    }
}

/// [`evaluate_inner`] behind a per-candidate panic boundary: a panicking
/// planner (or any downstream pipeline bug) becomes a typed
/// [`Outcome::Panicked`] row instead of unwinding into the worker pool
/// and aborting the whole search.
fn evaluate(
    model: &Model,
    planner: &'static dyn Planner,
    spec: &PlanSpec,
    cluster: &Cluster,
    comm: CommMode,
    spread: bool,
    cache: Option<&ArtifactCache>,
) -> Candidate {
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        evaluate_inner(model, planner, spec, cluster, comm, spread, cache)
    }));
    caught.unwrap_or_else(|payload| {
        let msg = payload
            .downcast_ref::<&str>()
            .map(|s| s.to_string())
            .or_else(|| payload.downcast_ref::<String>().cloned())
            .unwrap_or_else(|| "non-string panic payload".to_string());
        Candidate {
            planner: planner.name(),
            spec: spec.clone(),
            plan_name: String::new(),
            outcome: Outcome::Panicked(msg),
        }
    })
}

fn evaluate_inner(
    model: &Model,
    planner: &'static dyn Planner,
    spec: &PlanSpec,
    cluster: &Cluster,
    comm: CommMode,
    spread: bool,
    cache: Option<&ArtifactCache>,
) -> Candidate {
    // One spec clone up front, moved into whichever outcome arm fires.
    let spec = spec.clone();
    match planner.build(model, &spec) {
        Err(e) => Candidate {
            planner: planner.name(),
            spec,
            plan_name: String::new(),
            outcome: Outcome::BuildError(e.to_string()),
        },
        Ok(out) => {
            let PlanOutput { graph, mut schedule, name } = out;
            if spread {
                apply_rack_spread(&mut schedule, &spec, cluster);
            }
            match schedule::validate(&graph, &schedule) {
                Err(e) => Candidate {
                    planner: planner.name(),
                    spec,
                    plan_name: name,
                    outcome: Outcome::ScheduleError(e.to_string()),
                },
                Ok(vs) => {
                    let plan = materialize::materialize(&graph, &vs, cluster, comm);
                    let tg = sim::TaskGraph::prepare(&vs, &plan);
                    let r = sim::simulate_prepared(&graph, &tg, &plan, cluster);
                    let (_, _, bubble) = r.breakdown();
                    let metrics = Metrics {
                        makespan: r.makespan,
                        des_makespan: None,
                        des_oom: false,
                        aggregate_tflops: r.aggregate_tflops,
                        comm_bytes: r.comm_bytes,
                        peak_mem: r.max_peak_mem(),
                        bubble_frac: bubble / r.makespan.max(1e-12),
                        oom: r.oom,
                        gap: None,
                        goodput: None,
                        recovery: None,
                    };
                    // Valid non-OOM candidates may reach the DES re-rank
                    // head: hand the artifacts to the bounded cache instead
                    // of rebuilding them there.
                    if let Some(cache) = cache {
                        if !r.oom {
                            cache.offer(
                                r.makespan,
                                cand_key(planner.name(), &spec),
                                DesArtifacts { graph, tg, plan },
                            );
                        }
                    }
                    Candidate {
                        planner: planner.name(),
                        spec,
                        plan_name: name,
                        outcome: Outcome::Ok(metrics),
                    }
                }
            }
        }
    }
}

/// Run the full search: enumerate + prune the spec grid, dominance-prune
/// against the analytic lower bound, evaluate every survivor in parallel
/// against the **borrowed** probe model (built exactly once by the caller;
/// workers share it read-only and clone only the graph per build), rank
/// deterministically.
///
/// Dominance pruning is two-phase so it stays deterministic under any
/// worker count: candidates are sorted by lower bound, the best-bounded
/// [`PRUNE_SEED`] prefix is simulated first, and the remaining candidates
/// are skipped iff their *bound* exceeds the best *simulated* seed time —
/// such a candidate's true time can only be worse, so the optimum is never
/// pruned.
pub fn search(model: &Model, cluster: &Cluster, cfg: &SearchConfig) -> SearchReport {
    let t0 = std::time::Instant::now();
    let model_name = model.name.clone();
    let stats = ModelStats::of(&model.graph);
    let (cands, pruned, mut excluded) =
        enumerate_constrained(model, cluster, cfg.hetero, cfg.dp_min.max(1));
    // ---- fourth axis: pin the grid to one schedule ----
    // Every spec is re-labeled with the pinned `sched{...}` token; pins a
    // family cannot honor count as config exclusions (not infeasibility),
    // and specs that collapse to the same (planner, label) dedup.
    let cands = if let Some(s) = &cfg.schedule {
        let mut seen = std::collections::HashSet::new();
        let mut pinned: Vec<(&'static dyn Planner, PlanSpec)> = Vec::new();
        for (p, mut spec) in cands {
            spec.sched = Some(s.clone());
            if feasibility(&spec, model, cluster).is_err()
                || !seen.insert(cand_key(p.name(), &spec))
            {
                excluded += 1;
                continue;
            }
            pinned.push((p, spec));
        }
        pinned
    } else {
        cands
    };
    // Sort by analytic lower bound (stable tie-break on the enumeration
    // order via sort_by's stability) so both the candidate cap and the
    // pruning seed keep the most promising specs.
    let mut cands: Vec<(f64, &'static dyn Planner, PlanSpec)> = cands
        .into_iter()
        .map(|(p, spec)| (cluster.plan_time_lower_bound(&spec, &stats), p, spec))
        .collect();
    cands.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal));
    let mut capped = 0;
    if cfg.max_candidates > 0 && cands.len() > cfg.max_candidates {
        capped = cands.len() - cfg.max_candidates;
        cands.truncate(cfg.max_candidates);
    }
    let workers = if cfg.workers > 0 {
        cfg.workers
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    };
    let comm = cfg.comm;
    // The DES artifact cache only exists (and only costs memory) when a
    // re-rank will consume it.
    let cache =
        if cfg.fidelity == Fidelity::Des { Some(ArtifactCache::new(cfg.des_top)) } else { None };
    let spread = cfg.resilience.as_ref().map(|r| r.spread).unwrap_or(false);
    let eval_at = |i: usize| -> Candidate {
        let (_, p, spec) = &cands[i];
        evaluate(model, *p, spec, cluster, comm, spread, cache.as_ref())
    };

    let seed_len = if cfg.prune { PRUNE_SEED.min(cands.len()) } else { cands.len() };
    let mut ranked = pool::par_map(seed_len, workers, &eval_at);
    let mut pruned_bound = 0;
    if seed_len < cands.len() {
        let best_seed = ranked
            .iter()
            .filter(|c| c.rank_class() == 0)
            .filter_map(|c| c.metrics().map(|m| m.makespan))
            .fold(f64::INFINITY, f64::min);
        let survivors: Vec<usize> = (seed_len..cands.len())
            .filter(|&i| cands[i].0 <= best_seed)
            .collect();
        pruned_bound = cands.len() - seed_len - survivors.len();
        ranked.extend(pool::par_map(survivors.len(), workers, |j| eval_at(survivors[j])));
    }
    let evaluated = ranked.len();
    ranked.sort_by(|a, b| {
        a.rank_class()
            .cmp(&b.rank_class())
            .then_with(|| {
                let ta = a.metrics().map(|m| m.makespan).unwrap_or(f64::INFINITY);
                let tb = b.metrics().map(|m| m.makespan).unwrap_or(f64::INFINITY);
                ta.partial_cmp(&tb).unwrap_or(std::cmp::Ordering::Equal)
            })
            .then_with(|| a.plan_name.cmp(&b.plan_name))
    });
    // ---- fidelity tier 3: DES re-rank of the top-k list candidates ----
    // Zero-rebuild: evaluation already cached the (graph, task graph,
    // plan) artifacts of the top `des_top` list-ranked candidates, so the
    // re-score feeds them straight to the discrete-event engine. A cache
    // miss (possible only when candidates tie exactly in makespan at the
    // cap boundary) falls back to rebuilding from the same borrowed model
    // — deterministic either way, and still no model reconstruction.
    let mut des_rescored = 0usize;
    if cfg.fidelity == Fidelity::Des {
        let k = ranked
            .iter()
            .take(cfg.des_top.max(1))
            .take_while(|c| c.rank_class() == 0)
            .count();
        let des_of = |i: usize| -> Option<(f64, bool)> {
            let c = &ranked[i];
            if let Some(art) =
                cache.as_ref().and_then(|ch| ch.take(&cand_key(c.planner, &c.spec)))
            {
                let r = des::execute(&art.graph, &art.plan, cluster, &art.tg);
                return Some((r.makespan, r.oom));
            }
            let planner = registry::find(c.planner)?;
            let out = planner.build(model, &c.spec).ok()?;
            let vs = schedule::validate(&out.graph, &out.schedule).ok()?;
            let plan = materialize::materialize(&out.graph, &vs, cluster, comm);
            let r = des::simulate(&out.graph, &vs, &plan, cluster);
            Some((r.makespan, r.oom))
        };
        let scores = pool::par_map(k, workers, &des_of);
        for (i, s) in scores.into_iter().enumerate() {
            if let Outcome::Ok(m) = &mut ranked[i].outcome {
                m.des_makespan = s.map(|(t, _)| t);
                m.des_oom = s.map(|(_, oom)| oom).unwrap_or(false);
                des_rescored += s.is_some() as usize;
            }
        }
        // Re-order the re-scored head: DES-OOM plans last, then by DES
        // time; entries whose re-score failed (or tied) fall back to their
        // list makespan, so they keep the list ranking rather than
        // drifting alphabetically. The tail keeps the list ranking.
        sort_des_head(&mut ranked[..k]);
    }
    // ---- tier 4: seeded MCMC refinement over the top candidates ----
    let mut refined = 0usize;
    let mut refine_summary: Option<RefineSummary> = None;
    if let Some(rcfg) = &cfg.refine {
        let s = refine::refine(model, cluster, comm, workers, rcfg, &mut ranked);
        refined = s.refined;
        refine_summary = Some(s);
    }
    // ---- resilience tier: fault-trace scoring of the ranking head ----
    // Each top valid candidate is rebuilt (with the same rack-spreading
    // pass evaluation used) and re-run through the DES twice — fault-free
    // for the base makespan, then under the resolved fault trace with
    // checkpoint/restart modeled — and the head re-sorts by
    // goodput-adjusted iteration time, so a plan that loses less work to
    // the same faults outranks a marginally faster but fragile one.
    let mut resilience_scored = 0usize;
    let mut resilience_best: Option<crate::fault::ResilienceReport> = None;
    if let Some(rcfg) = &cfg.resilience {
        let k = ranked
            .iter()
            .take(cfg.des_top.max(1))
            .take_while(|c| c.rank_class() == 0)
            .count();
        let res_of = |i: usize| -> Option<crate::fault::ResilienceReport> {
            let c = &ranked[i];
            let planner = registry::find(c.planner)?;
            let out = planner.build(model, &c.spec).ok()?;
            let PlanOutput { graph, mut schedule, name: _ } = out;
            if rcfg.spread {
                apply_rack_spread(&mut schedule, &c.spec, cluster);
            }
            let vs = schedule::validate(&graph, &schedule).ok()?;
            let plan = materialize::materialize(&graph, &vs, cluster, comm);
            let tg = sim::TaskGraph::prepare(&vs, &plan);
            crate::fault::evaluate_resilience(&graph, &plan, cluster, &tg, rcfg)
                .ok()
                .map(|(rep, _)| rep)
        };
        let scores = pool::par_map(k, workers, &res_of);
        let mut reports: Vec<Option<crate::fault::ResilienceReport>> = scores;
        for (i, s) in reports.iter().enumerate() {
            if let Outcome::Ok(m) = &mut ranked[i].outcome {
                m.goodput = s.as_ref().map(|r| r.goodput);
                m.recovery = s.as_ref().map(|r| r.recovery_time);
                resilience_scored += s.is_some() as usize;
            }
        }
        // Goodput-adjusted re-sort of the scored head: effective time =
        // best-fidelity makespan / goodput (unscored rows keep goodput 1).
        let mut order: Vec<usize> = (0..k).collect();
        order.sort_by(|&a, &b| {
            let key = |i: usize| {
                let m = ranked[i].metrics();
                let t = m
                    .map(|m| m.des_makespan.unwrap_or(m.makespan))
                    .unwrap_or(f64::INFINITY);
                let g = m.and_then(|m| m.goodput).unwrap_or(1.0).max(1e-9);
                (m.map(|m| m.des_oom).unwrap_or(true), t / g)
            };
            let (ka, kb) = (key(a), key(b));
            ka.0.cmp(&kb.0)
                .then_with(|| ka.1.partial_cmp(&kb.1).unwrap_or(std::cmp::Ordering::Equal))
                .then_with(|| ranked[a].plan_name.cmp(&ranked[b].plan_name))
        });
        let head: Vec<Candidate> = order.iter().map(|&i| ranked[i].clone()).collect();
        let head_reports: Vec<Option<crate::fault::ResilienceReport>> =
            order.iter().map(|&i| reports[i].take()).collect();
        ranked[..k].clone_from_slice(&head);
        resilience_best = head_reports.into_iter().next().flatten();
    }
    SearchReport {
        model: model_name,
        gpus: cluster.num_gpus(),
        topology: cluster.topology_label(),
        ranked,
        pruned,
        excluded,
        capped,
        pruned_bound,
        evaluated,
        fidelity: cfg.fidelity,
        des_rescored,
        refined,
        refine: refine_summary,
        resilience_scored,
        resilience: resilience_best,
        wall_secs: t0.elapsed().as_secs_f64(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::plans::PlanKind;

    #[test]
    fn feasibility_rejects_degree_mismatch() {
        let model = models::gpt3(0, 8, 256);
        let cluster = Cluster::v100(8);
        let bad = PlanSpec { dp: 3, ..PlanSpec::new(PlanKind::Dp) };
        assert!(matches!(
            feasibility(&bad, &model, &cluster),
            Err(Infeasible::DeviceMismatch { want: 8, got: 3 })
        ));
    }

    #[test]
    fn feasibility_rejects_dp_beyond_batch() {
        let model = models::gpt3(0, 2, 256);
        let cluster = Cluster::v100(8);
        let bad = PlanSpec { dp: 8, ..PlanSpec::new(PlanKind::Dp) };
        assert!(matches!(
            feasibility(&bad, &model, &cluster),
            Err(Infeasible::BatchTooSmall { .. })
        ));
    }

    #[test]
    fn feasibility_rejects_micro_beyond_batch() {
        let model = models::gpt3(0, 4, 256);
        let cluster = Cluster::v100(8);
        let bad = PlanSpec { dp: 2, pp: 2, tp: 2, micro: 4, ..PlanSpec::new(PlanKind::Megatron) };
        assert!(matches!(
            feasibility(&bad, &model, &cluster),
            Err(Infeasible::MicroTooFine { batch: 4, dp: 2, micro: 4 })
        ));
    }

    #[test]
    fn feasibility_accepts_the_canonical_megatron_grid() {
        let model = models::gpt3(0, 8, 256);
        let cluster = Cluster::v100(4);
        let spec = PlanSpec { pp: 4, micro: 4, ..PlanSpec::new(PlanKind::Megatron) };
        assert_eq!(feasibility(&spec, &model, &cluster), Ok(()));
    }

    #[test]
    fn feasibility_gates_the_schedule_axis() {
        let model = models::gpt3(0, 8, 256);
        let cluster = Cluster::v100(4);
        // Zero-bubble on a megatron pipeline is a legal fourth-axis point.
        let ok = PlanSpec {
            pp: 4,
            micro: 4,
            sched: Some(SchedSpec::Named(SchedName::ZeroBubble)),
            ..PlanSpec::new(PlanKind::Megatron)
        };
        assert_eq!(feasibility(&ok, &model, &cluster), Ok(()));
        // A schedule token on a pipeline-free family is rejected, typed.
        let dp = PlanSpec {
            dp: 4,
            sched: Some(SchedSpec::Named(SchedName::OneFOneB)),
            ..PlanSpec::new(PlanKind::Dp)
        };
        assert!(matches!(
            feasibility(&dp, &model, &cluster),
            Err(Infeasible::ScheduleUnsupported { .. })
        ));
        // Explicit rows whose arity disagrees with pp are rejected, typed.
        let bad = PlanSpec {
            pp: 4,
            micro: 4,
            sched: Some(SchedSpec::Explicit(crate::schedule::ScheduleSpec::one_f_one_b(2, 4))),
            ..PlanSpec::new(PlanKind::Megatron)
        };
        assert!(matches!(
            feasibility(&bad, &model, &cluster),
            Err(Infeasible::ScheduleUnsupported { .. })
        ));
    }

    struct PanickingPlanner;

    impl Planner for PanickingPlanner {
        fn kind(&self) -> PlanKind {
            PlanKind::Dp
        }
        fn name(&self) -> &'static str {
            "panicker"
        }
        fn description(&self) -> &'static str {
            "test stub whose build always panics"
        }
        fn applicable(&self, _: &Model) -> bool {
            true
        }
        fn default_spec(&self, _: usize, _: usize) -> PlanSpec {
            PlanSpec::new(PlanKind::Dp)
        }
        fn candidates(&self, _: &Model, _: &Cluster) -> Vec<PlanSpec> {
            Vec::new()
        }
        fn build(&self, _: &Model, _: &PlanSpec) -> crate::plans::PlanResult {
            panic!("synthetic planner failure")
        }
    }

    #[test]
    fn evaluation_catches_a_panicking_planner() {
        static PLANNER: PanickingPlanner = PanickingPlanner;
        let model = models::gpt3(0, 8, 256);
        let cluster = Cluster::v100(8);
        let spec = PlanSpec { dp: 8, ..PlanSpec::new(PlanKind::Dp) };
        let c = evaluate(&model, &PLANNER, &spec, &cluster, CommMode::InterRvd, false, None);
        match &c.outcome {
            Outcome::Panicked(msg) => {
                assert!(msg.contains("synthetic planner failure"), "payload kept: {msg}")
            }
            other => panic!("expected Outcome::Panicked, got {other:?}"),
        }
        // The typed row renders instead of killing the table.
        assert_eq!(c.rank_class(), 2);
    }

    #[test]
    fn resilience_tier_scores_the_head_and_reports_goodput() {
        let model = models::gpt3(0, 16, 256);
        let cluster = Cluster::v100(4);
        let rc = crate::fault::ResilienceConfig {
            trace: Some(crate::fault::FaultSpec::parse("crash:d0@0.001").unwrap()),
            ..Default::default()
        };
        let cfg = SearchConfig::builder()
            .workers(2)
            .hetero(false)
            .des_top(2)
            .resilience(Some(rc))
            .build();
        let report = search(&model, &cluster, &cfg);
        assert!(report.resilience_scored > 0, "head must be fault-scored");
        let best = report.best().expect("valid candidate");
        let m = best.metrics().unwrap();
        let g = m.goodput.expect("winner carries goodput");
        assert!(g > 0.0 && g <= 1.0, "goodput {g}");
        assert!(m.recovery.is_some());
        let res = report.resilience.expect("winner's resilience breakdown kept");
        assert!(res.faulted_makespan >= res.base_makespan);
    }

    #[test]
    fn pinned_schedule_restricts_and_relabels_the_grid() {
        let model = models::gpt3(0, 16, 256);
        let cluster = Cluster::v100(4);
        let cfg = SearchConfig::builder()
            .workers(2)
            .hetero(false)
            .fidelity(Fidelity::Des)
            .des_top(2)
            .schedule(Some(SchedSpec::Named(SchedName::ZeroBubble)))
            .build();
        let report = search(&model, &cluster, &cfg);
        assert!(report.evaluated > 0, "zb-pinned grid must keep pipelined candidates");
        assert!(report.excluded > 0, "schedule-incompatible specs must be counted");
        for c in &report.ranked {
            assert_eq!(c.spec.sched, Some(SchedSpec::Named(SchedName::ZeroBubble)));
            let label = c.spec.label();
            assert!(label.contains("sched{zb}"), "label carries the axis: {label}");
            let back = PlanSpec::parse(&label).unwrap();
            assert_eq!(back.sched, c.spec.sched);
        }
    }
}
