//! Seeded MCMC/hill-climbing refinement over the grid search's top
//! candidates (FlexFlow-style delta simulation, arXiv 1807.05358, adapted
//! to the SuperScaler plan space).
//!
//! Each of the top-k feasible candidates seeds one independent Markov
//! chain. A chain proposes a small plan mutation, re-scores it under the
//! discrete-event engine via [`BaseRun::replay`] (re-executing only the
//! event suffix the mutation can affect), and accepts/rejects with a
//! Metropolis criterion on DES makespan. Chains are deterministic given
//! `(seed, chain index)` and independent of the worker count.
//!
//! # Mutation set
//!
//! * **Stage-boundary move** — shift one pipeline-stage boundary by one
//!   layer. Directions are biased 3:1 toward the side whose inter-stage
//!   activation handoff is cheaper under [`rvd::stage_conversion_time`],
//!   so boundary moves are RVD-conversion-cost-aware.
//! * **Recompute / offload toggle** — flip one stage's flag.
//! * **Widen/narrow** — move half of one stage's devices to its neighbor
//!   (total device count preserved; co-shard stages are skipped).
//! * **Micro-batch resize** — double or halve `micro`.
//! * **Schedule-row permutation** — swap two adjacent (micro × F/B/W)
//!   slots in one stage's schedule row, keeping the row set structurally
//!   valid ([`mutate_schedule`]). The permuted rows are written back into
//!   the spec as an explicit [`SchedSpec`], so an accepted ordering
//!   survives in the `sched{...}` label token.
//! * **Adjacent-op swap** — swap two neighboring ops in one device's
//!   serial order (a micro-batch slot swap). This mutates the schedule,
//!   not the spec, so it replays against the *current* base run and
//!   usually touches only a short event suffix.
//!
//! Spec-level mutations re-materialize the whole plan from the mutated
//! [`PlanSpec`] (boundary moves write an explicit per-stage layer
//! partition, closing the balanced-split-only debt from the hetero
//! planner; schedule permutations write an explicit `sched{...}` row
//! set). Accepting a spec mutation discards any accumulated raw op
//! swaps, but since the schedule DSL landed an ordering improvement no
//! longer dies with them: a permutation the chain accepts is
//! spec-encodable data, and the winner re-materializes from its spec
//! label alone.
//!
//! # Optimality-gap certificates
//!
//! Every accepted state is certified against the analytic
//! [`Cluster::plan_time_lower_bound`]; a chain terminates early once its
//! best gap falls under [`RefineConfig::gap_target`]. The per-candidate
//! gap lands in [`Metrics::gap`] (the `gap` table column) and the best
//! across chains in [`RefineSummary::best_gap`].

use std::collections::{BTreeSet, HashMap};

use super::{feasibility, sort_des_head, Candidate, Outcome};
use crate::cost::{Cluster, ModelStats};
use crate::des::delta::{BaseRun, DEFAULT_EPOCHS};
use crate::graph::TensorKind;
use crate::materialize::{self, CommMode, Plan};
use crate::models::Model;
use crate::plans::{balance_stages, registry, PlanKind, PlanSpec};
use crate::schedule::{self, DeviceId, SchedName, SchedSpec, ValidatedSchedule};
use crate::sim::TaskGraph;
use crate::util::pool;
use crate::util::rng::Rng;

/// Metropolis temperature as a fraction of the current makespan: an
/// uphill move costing 3% of the iteration time is accepted with
/// probability `1/e`.
const T_FRAC: f64 = 0.03;

/// Configuration of the refinement tier (`search --refine`).
#[derive(Clone, Debug)]
pub struct RefineConfig {
    /// Mutation budget per chain.
    pub iters: usize,
    /// Base RNG seed; chain `i` derives its own stream from `(seed, i)`.
    pub seed: u64,
    /// Number of top candidates refined (capped by the feasible head).
    pub top: usize,
    /// A chain stops early once its best gap certificate is at or under
    /// this fraction (0.01 = within 1% of the lower bound).
    pub gap_target: f64,
}

impl Default for RefineConfig {
    fn default() -> Self {
        RefineConfig { iters: 64, seed: 0x5ca1e, top: 4, gap_target: 0.01 }
    }
}

/// Aggregate accounting of one refinement pass, reported in
/// [`super::SearchReport::refine`] and the bench JSON.
#[derive(Clone, Debug, Default)]
pub struct RefineSummary {
    /// Chains launched (top candidates eligible for refinement).
    pub chains: usize,
    /// Chains that completed and wrote refined metrics back.
    pub refined: usize,
    /// Total mutations proposed across chains.
    pub iters: usize,
    /// Total mutations accepted across chains.
    pub accepted: usize,
    /// Events actually re-executed by delta replays.
    pub replayed_events: usize,
    /// Events a from-scratch run of every evaluated proposal would have
    /// executed (the delta-replay denominator).
    pub full_events: usize,
    /// Best (smallest) gap certificate across chains after refinement.
    pub best_gap: Option<f64>,
    /// Best non-OOM DES makespan of the chain seeds (the grid winners).
    pub start_best: Option<f64>,
    /// Best non-OOM DES makespan after refinement; never worse than
    /// [`RefineSummary::start_best`] because each chain's best starts at
    /// its seed score.
    pub best: Option<f64>,
}

impl RefineSummary {
    /// Fraction of events delta replay actually re-executed, vs full
    /// re-simulation of every evaluated proposal. `None` before any
    /// proposal was scored.
    pub fn delta_replay_frac(&self) -> Option<f64> {
        (self.full_events > 0).then(|| self.replayed_events as f64 / self.full_events as f64)
    }
}

/// `(oom, makespan)` — OOM states always rank behind non-OOM ones.
type Score = (bool, f64);

fn score_lt(a: Score, b: Score) -> bool {
    match (a.0, b.0) {
        (false, true) => true,
        (true, false) => false,
        _ => a.1 < b.1,
    }
}

struct ChainResult {
    start: Score,
    best: Score,
    gap: Option<f64>,
    iters: usize,
    accepted: usize,
    replayed: usize,
    full_events: usize,
}

/// Everything needed to score (and keep mutating) one plan instance.
struct Artifacts {
    graph: crate::graph::Graph,
    vs: ValidatedSchedule,
    plan: Plan,
    tg: TaskGraph,
}

fn build_artifacts(
    model: &Model,
    cluster: &Cluster,
    comm: CommMode,
    planner: &str,
    spec: &PlanSpec,
) -> Option<Artifacts> {
    let p = registry::find(planner)?;
    let out = p.build(model, spec).ok()?;
    let vs = schedule::validate(&out.graph, &out.schedule).ok()?;
    let plan = materialize::materialize(&out.graph, &vs, cluster, comm);
    let tg = TaskGraph::prepare(&vs, &plan);
    Some(Artifacts { graph: out.graph, vs, plan, tg })
}

/// Refine the head of `ranked` in place: each eligible candidate's DES
/// metrics are replaced by its chain's best, `gap` certificates are
/// attached, and the head is re-sorted so the refined winner leads.
pub fn refine(
    model: &Model,
    cluster: &Cluster,
    comm: CommMode,
    workers: usize,
    cfg: &RefineConfig,
    ranked: &mut [Candidate],
) -> RefineSummary {
    let k = ranked
        .iter()
        .take(cfg.top.max(1))
        .take_while(|c| c.rank_class() == 0)
        .count();
    let mut sum = RefineSummary { chains: k, ..RefineSummary::default() };
    if k == 0 {
        return sum;
    }
    let stats = ModelStats::of(&model.graph);
    let act_bytes = layer_act_bytes(model);
    let results: Vec<Option<ChainResult>> = {
        let head = &*ranked;
        pool::par_map(k, workers, |i| {
            run_chain(model, cluster, comm, &stats, &act_bytes, cfg, &head[i], i)
        })
    };
    let fold_min = |slot: &mut Option<f64>, s: Score| {
        if !s.0 && slot.map(|v| s.1 < v).unwrap_or(true) {
            *slot = Some(s.1);
        }
    };
    for (i, r) in results.into_iter().enumerate() {
        let Some(r) = r else { continue };
        if let Outcome::Ok(m) = &mut ranked[i].outcome {
            m.des_makespan = Some(r.best.1);
            m.des_oom = r.best.0;
            m.gap = r.gap;
        }
        sum.refined += 1;
        sum.iters += r.iters;
        sum.accepted += r.accepted;
        sum.replayed_events += r.replayed;
        sum.full_events += r.full_events;
        fold_min(&mut sum.start_best, r.start);
        fold_min(&mut sum.best, r.best);
    }
    sort_des_head(&mut ranked[..k]);
    sum.best_gap = ranked.first().and_then(|c| c.metrics()).and_then(|m| m.gap);
    sum
}

/// Gap certificate from a makespan and an analytic lower bound. `None`
/// when the bound is degenerate (zero, negative, or non-finite) — dividing
/// by a vanishing bound would manufacture astronomically large "gaps" that
/// sort refined candidates nonsensically; an absent certificate sorts as
/// "unknown" instead and can never satisfy `gap_target`.
fn gap_from_lb(makespan: f64, lb: f64) -> Option<f64> {
    (lb.is_finite() && lb > 0.0 && makespan.is_finite())
        .then(|| (makespan / lb - 1.0).max(0.0))
}

fn gap_of(cluster: &Cluster, stats: &ModelStats, spec: &PlanSpec, makespan: f64) -> Option<f64> {
    gap_from_lb(makespan, cluster.plan_time_lower_bound(spec, stats))
}

fn metropolis(rng: &mut Rng, cur: Score, new: Score) -> bool {
    match (cur.0, new.0) {
        (false, true) => false,
        (true, false) => true,
        (true, true) => new.1 <= cur.1,
        (false, false) => {
            new.1 <= cur.1
                || rng.f64() < (-(new.1 - cur.1) / (T_FRAC * cur.1.max(1e-12))).exp()
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn run_chain(
    model: &Model,
    cluster: &Cluster,
    comm: CommMode,
    stats: &ModelStats,
    act_bytes: &[u64],
    cfg: &RefineConfig,
    cand: &Candidate,
    index: usize,
) -> Option<ChainResult> {
    let mut rng = Rng::new(cfg.seed.wrapping_add((index as u64).wrapping_mul(0x9E3779B97F4A7C15)));
    let mut spec = cand.spec.clone();
    let mut art = build_artifacts(model, cluster, comm, cand.planner, &spec)?;
    let (mut base, rep) = BaseRun::capture(&art.graph, &art.plan, cluster, &art.tg, DEFAULT_EPOCHS);
    let mut cur: Score = (rep.oom, rep.makespan);
    let start = cur;
    let mut best = cur;
    let mut best_gap = gap_of(cluster, stats, &spec, cur.1);
    // Proposal score memo: revisited states (flag toggles, micro
    // oscillation) cost zero replayed events.
    let mut memo: HashMap<u64, Score> = HashMap::new();
    let hetero = spec.stages.is_some();
    let (mut iters, mut accepted, mut replayed, mut full_events) = (0usize, 0usize, 0usize, 0usize);
    for _ in 0..cfg.iters {
        if best_gap.map_or(false, |g| g <= cfg.gap_target) {
            break;
        }
        iters += 1;
        let r = rng.below(100);
        let want_swap = if hetero { r < 40 } else { r < 60 };
        if want_swap && art.tg.serial_hints {
            let Some((d, pos)) = propose_swap(&art.vs, &mut rng) else { continue };
            let mut vs2 = art.vs.clone();
            vs2.device_order.get_mut(&d).unwrap().swap(pos, pos + 1);
            let tg2 = TaskGraph::prepare(&vs2, &art.plan);
            if !tg2.serial_hints {
                // The swapped order is cyclic against data deps; prepare
                // dropped the hints, so this is not the proposed state.
                continue;
            }
            let key = swap_key(&spec, &vs2);
            let hit = memo.get(&key).copied();
            let (score, ran_base) = match hit {
                Some(s) => (s, None),
                None => {
                    let (rep2, rs, base2) = base.replay(&art.graph, &art.plan, cluster, &tg2);
                    replayed += rs.replayed;
                    full_events += rs.total;
                    let s = (rep2.oom, rep2.makespan);
                    memo.insert(key, s);
                    (s, Some(base2))
                }
            };
            if metropolis(&mut rng, cur, score) {
                let base2 = match ran_base {
                    Some(b) => b,
                    None => {
                        // Memo hit told us the score; re-run the replay to
                        // obtain the promoted base for further mutations.
                        let (_, rs, b) = base.replay(&art.graph, &art.plan, cluster, &tg2);
                        replayed += rs.replayed;
                        full_events += rs.total;
                        b
                    }
                };
                art.vs = vs2;
                art.tg = tg2;
                base = base2;
                cur = score;
                accepted += 1;
                if score_lt(score, best) {
                    best = score;
                    best_gap = gap_of(cluster, stats, &spec, score.1);
                }
            }
        } else {
            let prop = if hetero {
                if r < 58 || want_swap {
                    mutate_boundary(model, cluster, act_bytes, &spec, &mut rng)
                } else if r < 68 {
                    mutate_flag(&spec, &mut rng, false)
                } else if r < 76 {
                    mutate_flag(&spec, &mut rng, true)
                } else if r < 88 {
                    mutate_width(&spec, &mut rng)
                } else {
                    Some(mutate_micro(&spec, &mut rng))
                }
            } else if r < 80 {
                Some(mutate_micro(&spec, &mut rng))
            } else {
                mutate_schedule(&spec, &mut rng)
            };
            let Some(s2) = prop else { continue };
            if s2 == spec || feasibility(&s2, model, cluster).is_err() {
                continue;
            }
            let key = spec_key(cand.planner, &s2);
            let hit = memo.get(&key).copied();
            let (score, built) = match hit {
                Some(s) => (s, None),
                None => {
                    let Some(art2) = build_artifacts(model, cluster, comm, cand.planner, &s2)
                    else {
                        continue;
                    };
                    let (rep2, rs, base2) = base.replay(&art2.graph, &art2.plan, cluster, &art2.tg);
                    replayed += rs.replayed;
                    full_events += rs.total;
                    let s = (rep2.oom, rep2.makespan);
                    memo.insert(key, s);
                    (s, Some((art2, base2)))
                }
            };
            if metropolis(&mut rng, cur, score) {
                let (art2, base2) = match built {
                    Some(ab) => ab,
                    None => {
                        let art2 = build_artifacts(model, cluster, comm, cand.planner, &s2)?;
                        let (_, rs, base2) =
                            base.replay(&art2.graph, &art2.plan, cluster, &art2.tg);
                        replayed += rs.replayed;
                        full_events += rs.total;
                        (art2, base2)
                    }
                };
                // Rebuilding from the spec discards any accumulated op
                // swaps — the chain restarts schedule-space exploration
                // from the canonical order of the new spec.
                art = art2;
                base = base2;
                spec = s2;
                cur = score;
                accepted += 1;
                if score_lt(score, best) {
                    best = score;
                    best_gap = gap_of(cluster, stats, &spec, score.1);
                }
            }
        }
    }
    Some(ChainResult { start, best, gap: best_gap, iters, accepted, replayed, full_events })
}

// ---- mutations --------------------------------------------------------

/// Move one stage boundary by one layer, 3:1 biased toward the direction
/// whose inter-stage RVD conversion is cheaper. Writes the full explicit
/// layer partition into the mutated spec so the hetero planner reproduces
/// exactly this split.
fn mutate_boundary(
    model: &Model,
    cluster: &Cluster,
    act_bytes: &[u64],
    spec: &PlanSpec,
    rng: &mut Rng,
) -> Option<PlanSpec> {
    let stages = spec.stages.as_ref()?;
    let pp = stages.len();
    let nlayers = model.layers.len();
    if pp < 2 || nlayers < pp {
        return None;
    }
    let explicit = stages.iter().all(|s| s.layers > 0)
        && stages.iter().map(|s| s.layers).sum::<usize>() == nlayers;
    let mut sizes: Vec<usize> = if explicit {
        stages.iter().map(|s| s.layers).collect()
    } else {
        balance_stages(&model.graph, &model.layers, pp)
            .iter()
            .map(|v| v.len())
            .collect()
    };
    let b = rng.range(0, pp - 1);
    // First layer index of stage b+1 — the cut this move shifts.
    let cut: usize = sizes[..=b].iter().sum();
    let widths: Vec<usize> = stages.iter().map(|s| s.width()).collect();
    let groups = stage_groups(&widths);
    let handoff = |cut_new: usize| {
        crate::rvd::stage_conversion_time(
            cluster,
            &groups[b],
            &groups[b + 1],
            act_bytes.get(cut_new.wrapping_sub(1)).copied().unwrap_or(0),
        )
    };
    let left_ok = sizes[b] > 1;
    let right_ok = sizes[b + 1] > 1;
    let dir: i64 = match (left_ok, right_ok) {
        (false, false) => return None,
        (true, false) => -1,
        (false, true) => 1,
        (true, true) => {
            let cheaper = if handoff(cut - 1) <= handoff(cut + 1) { -1 } else { 1 };
            if rng.below(4) < 3 {
                cheaper
            } else {
                -cheaper
            }
        }
    };
    if dir < 0 {
        sizes[b] -= 1;
        sizes[b + 1] += 1;
    } else {
        sizes[b] += 1;
        sizes[b + 1] -= 1;
    }
    let mut out = spec.clone();
    for (st, &sz) in out.stages.as_mut().unwrap().iter_mut().zip(&sizes) {
        st.layers = sz;
    }
    Some(out)
}

/// Move half of one stage's devices to an adjacent stage (widen one,
/// narrow the other; total device count is preserved so the spec keeps
/// matching the cluster). Co-shard stages are skipped.
fn mutate_width(spec: &PlanSpec, rng: &mut Rng) -> Option<PlanSpec> {
    let stages = spec.stages.as_ref()?;
    let pp = stages.len();
    if pp < 2 {
        return None;
    }
    let b = rng.range(0, pp - 1);
    if stages[b].shards.max(1) > 1 || stages[b + 1].shards.max(1) > 1 {
        return None;
    }
    let (w1, w2) = (stages[b].width(), stages[b + 1].width());
    let mut opts: Vec<(usize, usize)> = Vec::new();
    if w1 >= 2 {
        opts.push((w1 - w1 / 2, w2 + w1 / 2));
    }
    if w2 >= 2 {
        opts.push((w1 + w2 / 2, w2 - w2 / 2));
    }
    if opts.is_empty() {
        return None;
    }
    let (nw1, nw2) = *rng.choose(&opts);
    let mut out = spec.clone();
    let st = out.stages.as_mut().unwrap();
    st[b].tp = nw1;
    st[b + 1].tp = nw2;
    Some(out)
}

/// Toggle one stage's recompute (`offload == false`) or offload flag.
fn mutate_flag(spec: &PlanSpec, rng: &mut Rng, offload: bool) -> Option<PlanSpec> {
    let mut out = spec.clone();
    let stages = out.stages.as_mut()?;
    let i = rng.range(0, stages.len());
    if offload {
        stages[i].offload = !stages[i].offload;
    } else {
        stages[i].recompute = !stages[i].recompute;
    }
    Some(out)
}

/// Permute one stage's schedule row: swap two adjacent (micro × F/B/W)
/// slots, keeping the row set structurally valid
/// ([`crate::schedule::ScheduleSpec::check`]). The permuted rows are
/// written back as an explicit [`SchedSpec`], so an accepted ordering is
/// part of the spec label (`sched{...}`) and re-materializes from the
/// label alone — unlike raw device-order swaps, which mutate the built
/// schedule but not the spec.
pub fn mutate_schedule(spec: &PlanSpec, rng: &mut Rng) -> Option<PlanSpec> {
    if spec.stages.is_some() {
        return None; // hetero pipelines are 1F1B-only (see sched_feasibility)
    }
    let (pp, k) = (spec.pp.max(1), spec.micro.max(1));
    if pp < 2 || k < 2 {
        return None;
    }
    // The family's planner default when the spec carries no token yet.
    let default = match spec.kind {
        PlanKind::GPipe => SchedName::Sync,
        _ => SchedName::OneFOneB,
    };
    let base = spec.sched.clone().unwrap_or(SchedSpec::Named(default)).resolve(pp, k);
    for _ in 0..8 {
        let s = rng.range(0, pp);
        let row_len = base.rows[s].len();
        if row_len < 2 {
            continue;
        }
        let pos = rng.range(0, row_len - 1);
        let mut rows = base.clone();
        rows.rows[s].swap(pos, pos + 1);
        if rows.rows[s] == base.rows[s] || rows.check(k).is_err() {
            continue;
        }
        let mut out = spec.clone();
        out.sched = Some(SchedSpec::Explicit(rows));
        return Some(out);
    }
    None
}

/// Double or halve the micro-batch count; infeasible values (micro beyond
/// the batch) are rejected by the caller's feasibility check.
fn mutate_micro(spec: &PlanSpec, rng: &mut Rng) -> PlanSpec {
    let mut out = spec.clone();
    if rng.f64() < 0.5 && out.micro >= 2 {
        out.micro /= 2;
    } else {
        out.micro = out.micro.max(1) * 2;
    }
    out
}

/// Pick a device with ≥ 2 serially-ordered ops and an adjacent position
/// pair to swap.
fn propose_swap(vs: &ValidatedSchedule, rng: &mut Rng) -> Option<(DeviceId, usize)> {
    let mut devs: Vec<DeviceId> = vs
        .device_order
        .iter()
        .filter(|(_, ops)| ops.len() >= 2)
        .map(|(&d, _)| d)
        .collect();
    if devs.is_empty() {
        return None;
    }
    devs.sort_unstable();
    let d = *rng.choose(&devs);
    let len = vs.device_order[&d].len();
    Some((d, rng.range(0, len - 1)))
}

// ---- helpers ----------------------------------------------------------

/// Consecutive device groups of a stage-width vector (data-parallel
/// replica 0) — the groups the hetero planner assigns.
fn stage_groups(widths: &[usize]) -> Vec<Vec<DeviceId>> {
    let mut out = Vec::with_capacity(widths.len());
    let mut next = 0usize;
    for &w in widths {
        out.push((next..next + w).collect());
        next += w;
    }
    out
}

/// Per-layer activation bytes of the untransformed model: the payload a
/// stage boundary placed after that layer must hand to the next stage.
fn layer_act_bytes(model: &Model) -> Vec<u64> {
    model
        .layers
        .iter()
        .map(|ops| {
            let mut seen = BTreeSet::new();
            let mut total = 0u64;
            for &op in ops {
                for &v in &model.graph.op(op).outputs {
                    let pt = model.graph.vtensor(v).ptensor;
                    if model.graph.ptensor(pt).kind == TensorKind::Activation && seen.insert(pt) {
                        total += model.graph.ptensor(pt).bytes();
                    }
                }
            }
            total
        })
        .collect()
}

fn fnv(h: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *h ^= b as u64;
        *h = h.wrapping_mul(0x100000001b3);
    }
}

/// Memo key of a spec-level proposal: scores of rebuilt specs depend only
/// on the spec itself, never on the chain's current state.
fn spec_key(planner: &str, spec: &PlanSpec) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    fnv(&mut h, b"spec|");
    fnv(&mut h, planner.as_bytes());
    fnv(&mut h, spec.label().as_bytes());
    h
}

/// Memo key of a schedule-swap proposal: the full device order matters
/// (and the spec it materialized from), since swap scores are relative to
/// the current plan.
fn swap_key(spec: &PlanSpec, vs: &ValidatedSchedule) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    fnv(&mut h, b"swap|");
    fnv(&mut h, spec.label().as_bytes());
    let mut devs: Vec<DeviceId> = vs.device_order.keys().copied().collect();
    devs.sort_unstable();
    for d in devs {
        fnv(&mut h, &d.to_le_bytes());
        for &op in &vs.device_order[&d] {
            fnv(&mut h, &op.to_le_bytes());
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::plans::{PlanKind, StageSpec};

    fn hetero_spec() -> PlanSpec {
        PlanSpec {
            pp: 2,
            micro: 2,
            stages: Some(vec![StageSpec::tp(2), StageSpec::tp(2)]),
            ..PlanSpec::new(PlanKind::Hetero)
        }
    }

    #[test]
    fn boundary_move_writes_a_complete_explicit_partition() {
        let model = models::gpt3(0, 8, 256);
        let cluster = Cluster::v100(4);
        let act = layer_act_bytes(&model);
        let spec = hetero_spec();
        let mut rng = Rng::new(7);
        let m = mutate_boundary(&model, &cluster, &act, &spec, &mut rng)
            .expect("boundary move applies to a 2-stage spec");
        let stages = m.stages.as_ref().unwrap();
        assert!(stages.iter().all(|s| s.layers > 0));
        assert_eq!(
            stages.iter().map(|s| s.layers).sum::<usize>(),
            model.layers.len(),
            "partition must cover every layer exactly once"
        );
        // A second move from the mutated spec starts from its explicit
        // partition, not the balanced one.
        let m2 = mutate_boundary(&model, &cluster, &act, &m, &mut rng).unwrap();
        assert_eq!(
            m2.stages.as_ref().unwrap().iter().map(|s| s.layers).sum::<usize>(),
            model.layers.len()
        );
    }

    #[test]
    fn width_move_preserves_total_device_count() {
        let spec = hetero_spec();
        let mut rng = Rng::new(11);
        for _ in 0..32 {
            if let Some(m) = mutate_width(&spec, &mut rng) {
                assert_eq!(m.devices(), spec.devices());
            }
        }
    }

    #[test]
    fn micro_mutation_oscillates_between_feasible_neighbors() {
        let spec = hetero_spec();
        let mut rng = Rng::new(3);
        let mut seen = BTreeSet::new();
        for _ in 0..64 {
            seen.insert(mutate_micro(&spec, &mut rng).micro);
        }
        assert!(seen.contains(&1) && seen.contains(&4), "halve and double both reachable");
    }

    #[test]
    fn accepted_schedule_permutations_rematerialize_from_the_label() {
        // The PR-6 debt, closed: a schedule-order mutation is spec data,
        // so the mutated winner rebuilds from its label alone.
        let model = models::gpt3(0, 8, 256);
        let cluster = Cluster::v100(4);
        let spec = PlanSpec { pp: 4, micro: 4, ..PlanSpec::new(PlanKind::Megatron) };
        let mut rng = Rng::new(5);
        let mut found = None;
        for _ in 0..32 {
            if let Some(m) = mutate_schedule(&spec, &mut rng) {
                found = Some(m);
                break;
            }
        }
        let m = found.expect("a valid adjacent-slot permutation of 1F1B exists");
        let sched = m.sched.as_ref().expect("mutation writes an explicit schedule");
        assert!(matches!(sched, SchedSpec::Explicit(_)));
        let label = m.label();
        assert!(label.contains("sched{"), "label carries the permutation: {label}");
        let back = PlanSpec::parse(&label).unwrap();
        assert_eq!(back, m, "value-level round-trip through the label");
        assert_eq!(feasibility(&back, &model, &cluster), Ok(()));
        let art = build_artifacts(&model, &cluster, CommMode::InterRvd, "megatron", &back);
        assert!(art.is_some(), "permuted schedule must rebuild and validate from the label");
    }

    #[test]
    fn schedule_mutation_skips_unschedulable_specs() {
        let mut rng = Rng::new(9);
        // Hetero (stage-list) specs are 1F1B-only.
        assert!(mutate_schedule(&hetero_spec(), &mut rng).is_none());
        // No pipeline / single micro-batch: nothing to permute.
        let dp = PlanSpec { dp: 4, ..PlanSpec::new(PlanKind::Dp) };
        assert!(mutate_schedule(&dp, &mut rng).is_none());
        let one = PlanSpec { pp: 4, micro: 1, ..PlanSpec::new(PlanKind::Megatron) };
        assert!(mutate_schedule(&one, &mut rng).is_none());
    }

    #[test]
    fn chain_is_deterministic_for_a_fixed_seed() {
        let model = models::gpt3(0, 8, 256);
        let cluster = Cluster::v100(4);
        let stats = ModelStats::of(&model.graph);
        let act = layer_act_bytes(&model);
        let cfg = RefineConfig { iters: 8, ..RefineConfig::default() };
        let cand = Candidate {
            planner: "hetero",
            spec: hetero_spec(),
            plan_name: String::new(),
            outcome: Outcome::BuildError(String::new()),
        };
        let a = run_chain(&model, &cluster, CommMode::InterRvd, &stats, &act, &cfg, &cand, 0)
            .expect("chain runs");
        let b = run_chain(&model, &cluster, CommMode::InterRvd, &stats, &act, &cfg, &cand, 0)
            .expect("chain runs");
        assert_eq!(a.best.1.to_bits(), b.best.1.to_bits());
        assert_eq!(a.gap.map(f64::to_bits), b.gap.map(f64::to_bits));
        assert_eq!(a.accepted, b.accepted);
        assert_eq!(a.replayed, b.replayed);
        assert!(a.best.1 <= a.start.1 || a.start.0, "best never regresses past the seed");
        assert!(a.gap.expect("gpt3@4 has a positive lower bound").is_finite());
    }

    #[test]
    fn degenerate_lower_bounds_yield_no_gap_certificate() {
        assert_eq!(gap_from_lb(1.0, 0.0), None);
        assert_eq!(gap_from_lb(1.0, -1.0), None);
        assert_eq!(gap_from_lb(1.0, f64::NAN), None);
        assert_eq!(gap_from_lb(1.0, f64::INFINITY), None);
        assert_eq!(gap_from_lb(f64::NAN, 1.0), None);
        // Sound bounds still certify: makespan 1.5 over lb 1.0 is a 50% gap,
        // and a makespan at the bound certifies optimality.
        assert!((gap_from_lb(1.5, 1.0).unwrap() - 0.5).abs() < 1e-12);
        assert_eq!(gap_from_lb(0.5, 1.0), Some(0.0));
    }
}
