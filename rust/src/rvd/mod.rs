//! RVD communication synthesis (paper §4, Figs. 10/11/18).
//!
//! The partitioning of a tensor across a device group is summarized as an
//! **RVD state** `R(r) V(v) D(k₁…kₙ)`: the tensor is replicated `r` times,
//! value-split into `v` additive partials, and dim-partitioned `kᵢ`-ways
//! along dim `i`, with `r·v·∏kᵢ = #devices`. Each communication primitive is
//! a *transition rule* between RVD states; composing a producer→consumer
//! redistribution becomes a shortest-path (Dijkstra) search over the RVD
//! transition graph with cost-model edge weights.
//!
//! Intra-RVD connects two states over the *same* device group; inter-RVD
//! glues the producer group's graph to the consumer group's with
//! RD-scatter / RD-gather / transfer cross edges (Fig. 10 g–h).
//!
//! Device layout convention: rank within the group = `(ri·v + vi)·∏d + dᵢ`
//! (replica slowest, dim partitions fastest, row-major over dims). The
//! subgroup participating in a transition is derived from the coordinate
//! stride, so NVLink vs InfiniBand costs fall out of the real device ids.

use crate::cost::Cluster;
use crate::graph::CollKind;
use crate::schedule::DeviceId;
use std::cmp::Ordering;
use std::collections::{BinaryHeap, HashMap};

/// An RVD partitioning state. `d.len()` is the tensor rank (fixed during a
/// search).
#[derive(Clone, PartialEq, Eq, Hash, Debug)]
pub struct Rvd {
    pub r: usize,
    pub v: usize,
    pub d: Vec<usize>,
}

impl Rvd {
    pub fn new(r: usize, v: usize, d: &[usize]) -> Rvd {
        assert!(r >= 1 && v >= 1 && d.iter().all(|&k| k >= 1));
        Rvd { r, v, d: d.to_vec() }
    }

    /// Fully-replicated state over `n` devices.
    pub fn replicated(n: usize, rank: usize) -> Rvd {
        Rvd::new(n, 1, &vec![1; rank])
    }

    pub fn num_devices(&self) -> usize {
        self.r * self.v * self.d.iter().product::<usize>()
    }

    pub fn d_prod(&self) -> usize {
        self.d.iter().product()
    }

    /// Bytes held per device for a tensor of `total_bytes` (replicas and
    /// value-partials hold full-shape shards; dim partitions slice them).
    pub fn shard_bytes(&self, total_bytes: u64) -> u64 {
        total_bytes / self.d_prod() as u64
    }

    pub fn rank(&self) -> usize {
        self.d.len()
    }
}

impl std::fmt::Display for Rvd {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let d = self
            .d
            .iter()
            .map(|k| k.to_string())
            .collect::<Vec<_>>()
            .join(",");
        write!(f, "R({})V({})D({})", self.r, self.v, d)
    }
}

/// One edge of a synthesized communication path.
#[derive(Clone, Debug, PartialEq)]
pub enum Transition {
    /// Local slice: replicas become dim-partitions (free). Fig. 10(a–c).
    Schunk { axis: usize, f: usize },
    /// Local: replicas become value-partials (free). Fig. 10(d).
    Vchunk { f: usize },
    /// D→R along `axis`. Fig. 10(e).
    AllGather { axis: usize, f: usize },
    /// V→R (all-reduce).
    AllReduce { f: usize },
    /// V→D along `axis`. Fig. 10(f).
    ReduceScatter { axis: usize, f: usize },
    /// Move a partition factor between dims.
    AllToAll { from: usize, to: usize, f: usize },
    /// Cross-group: each producer scatters its shard to `f` consumers,
    /// growing D(axis) by `f`. Fig. 10(h). `f == 1` is a plain transfer.
    RdScatter { axis: usize, f: usize },
    /// Cross-group: groups of `f` producers merge shards into one consumer,
    /// shrinking D(axis). Fig. 10(g).
    RdGather { axis: usize, f: usize },
}

impl Transition {
    /// Collective kind this transition maps to at execution time (`None`
    /// for free local slicing).
    pub fn collective(&self) -> Option<CollKind> {
        match self {
            Transition::Schunk { .. } | Transition::Vchunk { .. } => None,
            Transition::AllGather { .. } => Some(CollKind::AllGather),
            Transition::AllReduce { .. } => Some(CollKind::AllReduce),
            Transition::ReduceScatter { .. } => Some(CollKind::ReduceScatter),
            Transition::AllToAll { .. } => Some(CollKind::AllToAll),
            Transition::RdScatter { .. } => Some(CollKind::RdScatter),
            Transition::RdGather { .. } => Some(CollKind::RdGather),
        }
    }
}

impl std::fmt::Display for Transition {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Transition::Schunk { axis, f: k } => write!(f, "schunk(d{axis}x{k})"),
            Transition::Vchunk { f: k } => write!(f, "vchunk(x{k})"),
            Transition::AllGather { axis, f: k } => write!(f, "all-gather(d{axis}/{k})"),
            Transition::AllReduce { f: k } => write!(f, "all-reduce(x{k})"),
            Transition::ReduceScatter { axis, f: k } => {
                write!(f, "reduce-scatter(v/{k}->d{axis})")
            }
            Transition::AllToAll { from, to, f: k } => {
                write!(f, "all-to-all(d{from}->d{to}x{k})")
            }
            Transition::RdScatter { axis, f: k } => write!(f, "RD-scatter(d{axis}x{k})"),
            Transition::RdGather { axis, f: k } => write!(f, "RD-gather(d{axis}/{k})"),
        }
    }
}

/// A synthesized redistribution plan.
#[derive(Clone, Debug)]
pub struct Path {
    /// `(transition, state reached, step time)` triples.
    pub steps: Vec<(Transition, Rvd, f64)>,
    /// Total modeled time, seconds.
    pub time: f64,
}

impl Path {
    pub fn describe(&self, from: &Rvd) -> String {
        let mut s = format!("{from}");
        for (t, st, _) in &self.steps {
            s.push_str(&format!(" --{t}--> {st}"));
        }
        s
    }
}

fn divisors(n: usize) -> Vec<usize> {
    (2..=n).filter(|f| n % f == 0).collect()
}

/// Representative subgroup of `f` members: ranks `{i·stride}` mapped
/// through `group` to physical devices.
fn subgroup(group: &[DeviceId], stride: usize, f: usize) -> Vec<DeviceId> {
    (0..f).map(|i| group[(i * stride) % group.len()]).collect()
}

/// Enumerate intra-group transitions from `s` with modeled costs.
fn intra_edges(
    cluster: &Cluster,
    group: &[DeviceId],
    total_bytes: u64,
    s: &Rvd,
) -> Vec<(Transition, Rvd, f64)> {
    let mut out = Vec::new();
    let shard = s.shard_bytes(total_bytes);
    let dprod = s.d_prod();
    // Local: schunk / vchunk consume replication (free).
    for f in divisors(s.r) {
        for axis in 0..s.rank() {
            let mut t = s.clone();
            t.r /= f;
            t.d[axis] *= f;
            out.push((Transition::Schunk { axis, f }, t, 0.0));
        }
        let mut t = s.clone();
        t.r /= f;
        t.v *= f;
        out.push((Transition::Vchunk { f }, t, 0.0));
    }
    // all-gather: consume a dim factor, grow replication.
    for axis in 0..s.rank() {
        for f in divisors(s.d[axis]) {
            let mut t = s.clone();
            t.d[axis] /= f;
            t.r *= f;
            let stride: usize = s.d[axis + 1..].iter().product();
            let g = subgroup(group, stride.max(1), f);
            let cost = cluster.collective_time(CollKind::AllGather, &g, shard);
            out.push((Transition::AllGather { axis, f }, t, cost));
        }
    }
    // all-reduce: consume value splits, grow replication.
    for f in divisors(s.v) {
        let mut t = s.clone();
        t.v /= f;
        t.r *= f;
        let g = subgroup(group, dprod, f);
        let cost = cluster.collective_time(CollKind::AllReduce, &g, shard);
        out.push((Transition::AllReduce { f }, t, cost));
    }
    // reduce-scatter: value splits -> dim partitions.
    for f in divisors(s.v) {
        for axis in 0..s.rank() {
            let mut t = s.clone();
            t.v /= f;
            t.d[axis] *= f;
            let g = subgroup(group, dprod, f);
            // Ring reduce-scatter time is driven by the per-rank *output*
            // shard size.
            let cost =
                cluster.collective_time(CollKind::ReduceScatter, &g, shard / f as u64);
            out.push((Transition::ReduceScatter { axis, f }, t, cost));
        }
    }
    // all-to-all: move a partition factor between dims.
    for from in 0..s.rank() {
        for f in divisors(s.d[from]) {
            for to in 0..s.rank() {
                if to == from {
                    continue;
                }
                let mut t = s.clone();
                t.d[from] /= f;
                t.d[to] *= f;
                let stride: usize = s.d[from + 1..].iter().product();
                let g = subgroup(group, stride.max(1), f);
                let cost = cluster.collective_time(CollKind::AllToAll, &g, shard);
                out.push((Transition::AllToAll { from, to, f }, t, cost));
            }
        }
    }
    out
}

/// Cross-group edge time: `total_bytes` crossing the group boundary,
/// bottlenecked by the NICs of the narrower side (or NVLink if the two
/// groups share a server).
fn cross_time(cluster: &Cluster, src: &[DeviceId], dst: &[DeviceId], total_bytes: u64) -> f64 {
    let servers = |g: &[DeviceId]| {
        g.iter()
            .map(|&d| cluster.server_of(d))
            .collect::<std::collections::HashSet<_>>()
    };
    let ss = servers(src);
    let ds = servers(dst);
    if ss.is_subset(&ds) && ds.is_subset(&ss) && ss.len() == 1 {
        // Same single server: NVLink.
        return cluster.nvlink_lat + total_bytes as f64 / cluster.nvlink_bw;
    }
    let nics = ss.len().min(ds.len()).max(1) as f64;
    cluster.ib_lat + total_bytes as f64 / (cluster.ib_bw * nics)
}

#[derive(PartialEq)]
struct QItem {
    cost: f64,
    node: usize,
}
impl Eq for QItem {}
impl Ord for QItem {
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .cost
            .partial_cmp(&self.cost)
            .unwrap_or(Ordering::Equal)
    }
}
impl PartialOrd for QItem {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Node of the (possibly two-group) search graph: `side` 0 = producer
/// group, 1 = consumer group.
#[derive(Clone, PartialEq, Eq, Hash, Debug)]
struct Node {
    side: u8,
    state: Rvd,
}

fn dijkstra(
    cluster: &Cluster,
    src_group: &[DeviceId],
    dst_group: Option<&[DeviceId]>,
    total_bytes: u64,
    from: &Rvd,
    to: &Rvd,
) -> Option<Path> {
    let target_side = if dst_group.is_some() { 1 } else { 0 };
    let goal = Node { side: target_side, state: to.clone() };

    let mut ids: HashMap<Node, usize> = HashMap::new();
    let mut nodes: Vec<Node> = Vec::new();
    fn intern(n: Node, ids: &mut HashMap<Node, usize>, nodes: &mut Vec<Node>) -> usize {
        if let Some(&i) = ids.get(&n) {
            i
        } else {
            let i = nodes.len();
            ids.insert(n.clone(), i);
            nodes.push(n);
            i
        }
    }
    let s_id = intern(Node { side: 0, state: from.clone() }, &mut ids, &mut nodes);
    let mut dist: Vec<f64> = vec![f64::INFINITY; 1];
    let mut prev: Vec<Option<(usize, Transition, f64)>> = vec![None; 1];
    dist[s_id] = 0.0;
    let mut heap = BinaryHeap::new();
    heap.push(QItem { cost: 0.0, node: s_id });

    while let Some(QItem { cost, node }) = heap.pop() {
        if cost > dist[node] {
            continue;
        }
        let n = nodes[node].clone();
        if n == goal {
            let mut steps = Vec::new();
            let mut cur = node;
            while let Some((p, t, dt)) = prev[cur].clone() {
                steps.push((t, nodes[cur].state.clone(), dt));
                cur = p;
            }
            steps.reverse();
            return Some(Path { steps, time: cost });
        }
        let group = if n.side == 0 { src_group } else { dst_group.unwrap() };
        let mut edges: Vec<(Transition, Node, f64)> =
            intra_edges(cluster, group, total_bytes, &n.state)
                .into_iter()
                .map(|(t, st, c)| (t, Node { side: n.side, state: st }, c))
                .collect();
        // Cross edges producer-side -> consumer-side.
        if n.side == 0 {
            if let Some(dst) = dst_group {
                let n1 = src_group.len();
                let n2 = dst.len();
                // Bytes that must cross: one copy of every *distinct* shard
                // (dim shards × value partials); replicas don't resend.
                let distinct_bytes = n.state.shard_bytes(total_bytes)
                    * n.state.d_prod() as u64
                    * n.state.v as u64;
                if n2 % n1 == 0 {
                    let f = n2 / n1;
                    if f == 1 {
                        let c = cross_time(cluster, src_group, dst, distinct_bytes);
                        edges.push((
                            Transition::RdScatter { axis: 0, f: 1 },
                            Node { side: 1, state: n.state.clone() },
                            c,
                        ));
                    } else {
                        for axis in 0..n.state.rank() {
                            let mut t = n.state.clone();
                            t.d[axis] *= f;
                            let c = cross_time(cluster, src_group, dst, distinct_bytes);
                            edges.push((
                                Transition::RdScatter { axis, f },
                                Node { side: 1, state: t },
                                c,
                            ));
                        }
                    }
                } else if n1 % n2 == 0 {
                    let f = n1 / n2;
                    for axis in 0..n.state.rank() {
                        if n.state.d[axis] % f != 0 {
                            continue;
                        }
                        let mut t = n.state.clone();
                        t.d[axis] /= f;
                        let c = cross_time(cluster, src_group, dst, distinct_bytes);
                        edges.push((
                            Transition::RdGather { axis, f },
                            Node { side: 1, state: t },
                            c,
                        ));
                    }
                    // Replica-consuming gather: f replicas collapse to one
                    // consumer (only one copy crosses).
                    if n.state.r % f == 0 {
                        let mut t = n.state.clone();
                        t.r /= f;
                        let c = cross_time(
                            cluster,
                            src_group,
                            dst,
                            distinct_bytes,
                        );
                        edges.push((
                            Transition::RdGather { axis: 0, f },
                            Node { side: 1, state: t },
                            c,
                        ));
                    }
                }
            }
        }
        for (t, next, dc) in edges {
            let want = if next.side == 0 {
                src_group.len()
            } else {
                dst_group.map(|d| d.len()).unwrap_or(usize::MAX)
            };
            if next.state.num_devices() != want {
                continue;
            }
            let id = intern(next, &mut ids, &mut nodes);
            if id >= dist.len() {
                dist.resize(id + 1, f64::INFINITY);
                prev.resize(id + 1, None);
            }
            let nd = cost + dc;
            if nd < dist[id] {
                dist[id] = nd;
                prev[id] = Some((node, t, dc));
                heap.push(QItem { cost: nd, node: id });
            }
        }
    }
    None
}

/// Shortest redistribution between two RVD states over one device group
/// (intra-RVD, paper Fig. 11).
pub fn search_intra(
    cluster: &Cluster,
    group: &[DeviceId],
    total_bytes: u64,
    from: &Rvd,
    to: &Rvd,
) -> Option<Path> {
    assert_eq!(from.num_devices(), group.len(), "producer RVD vs group size");
    assert_eq!(to.num_devices(), group.len(), "consumer RVD vs group size");
    assert_eq!(from.rank(), to.rank());
    dijkstra(cluster, group, None, total_bytes, from, to)
}

/// Shortest redistribution between states on *different* device groups
/// (inter-RVD, paper Figs. 10(g–h), 18).
pub fn search_inter(
    cluster: &Cluster,
    src_group: &[DeviceId],
    dst_group: &[DeviceId],
    total_bytes: u64,
    from: &Rvd,
    to: &Rvd,
) -> Option<Path> {
    assert_eq!(from.num_devices(), src_group.len());
    assert_eq!(to.num_devices(), dst_group.len());
    assert_eq!(from.rank(), to.rank());
    dijkstra(cluster, src_group, Some(dst_group), total_bytes, from, to)
}

/// One step of a hierarchical cross-replica gradient synchronization: every
/// subgroup in `groups` runs the same collective concurrently; `bytes` is
/// the per-rank payload of each subgroup's collective. `time` is the
/// modeled duration of the step — the slowest subgroup's solo collective
/// time scaled by how many concurrent subgroups share its bottleneck link
/// (the NIC, for the cross-server step), so the planner-facing estimate
/// does not pretend the fan-out is free. The execution engines re-derive
/// contention themselves ([`Cluster::group_links`]); task durations stay
/// solo times there.
#[derive(Clone, Debug)]
pub struct SyncStep {
    pub kind: CollKind,
    pub groups: Vec<Vec<DeviceId>>,
    /// Per-rank payload of each subgroup collective, bytes.
    pub bytes: u64,
    /// Modeled step duration, seconds (contention-adjusted, see above).
    pub time: f64,
}

/// A gradient-sync decomposition over one data-parallel group — the
/// `V(n) → R(n)` RVD transition (§4) specialized to the gradient buffers a
/// dp plan must synchronize every iteration, exposed for planner use.
///
/// When the group has ≥ 2 members on each of ≥ 2 servers, the flat ring
/// all-reduce (whose bottleneck is the per-server NIC shared by all local
/// members) decomposes into **reduce-scatter within each server → ring
/// all-reduce across servers (one member per server and shard slot) →
/// all-gather within each server**: the cross-server traffic shrinks from
/// the whole buffer per local member to one shard per slot, exactly the
/// Fig. 18-style win the RVD abstraction exists to express. Irregular
/// layouts (one member per server, uneven membership, host participants)
/// keep the flat single-collective form.
#[derive(Clone, Debug)]
pub struct SyncPlan {
    /// Sequential steps; each step's subgroups run concurrently.
    pub steps: Vec<SyncStep>,
    /// Modeled total time, seconds (sum of step times).
    pub time: f64,
}

impl SyncPlan {
    /// Whether the sync decomposed beyond a single flat collective.
    pub fn is_hierarchical(&self) -> bool {
        self.steps.len() > 1
    }
}

/// Build the gradient-sync decomposition for `group`, where every member
/// holds a `bytes`-sized additive partial of the same gradient region.
/// Deterministic: picks the hierarchical form iff its modeled time beats
/// the flat all-reduce.
pub fn grad_sync_plan(cluster: &Cluster, group: &[DeviceId], bytes: u64) -> SyncPlan {
    let n = group.len();
    if n <= 1 {
        return SyncPlan { steps: Vec::new(), time: 0.0 };
    }
    let flat = |cluster: &Cluster| -> SyncPlan {
        let t = cluster.collective_time(CollKind::AllReduce, group, bytes);
        SyncPlan {
            steps: vec![SyncStep {
                kind: CollKind::AllReduce,
                groups: vec![group.to_vec()],
                bytes,
                time: t,
            }],
            time: t,
        }
    };
    // Bucket members per server, preserving group order. The host has no
    // NVLink peers to reduce-scatter with — keep it flat.
    let mut servers: Vec<(usize, Vec<DeviceId>)> = Vec::new();
    for &d in group {
        if d == crate::schedule::CPU_DEVICE {
            return flat(cluster);
        }
        let s = cluster.server_of(d);
        match servers.iter_mut().find(|(sv, _)| *sv == s) {
            Some((_, v)) => v.push(d),
            None => servers.push((s, vec![d])),
        }
    }
    let m = servers[0].1.len();
    if servers.len() < 2 || m < 2 || servers.iter().any(|(_, v)| v.len() != m) {
        return flat(cluster);
    }
    let shard = (bytes / m as u64).max(1);
    // Step 1: reduce-scatter the partials within each server (NVLink).
    let rs_groups: Vec<Vec<DeviceId>> = servers.iter().map(|(_, v)| v.clone()).collect();
    let rs_solo = rs_groups
        .iter()
        .map(|g| cluster.collective_time(CollKind::ReduceScatter, g, shard))
        .fold(0.0, f64::max);
    // Step 2: all-reduce each shard slot across servers — `m` concurrent
    // groups, one member per server, all funneling through the same NICs,
    // so the modeled step time is the solo time × m.
    let ar_groups: Vec<Vec<DeviceId>> =
        (0..m).map(|i| servers.iter().map(|(_, v)| v[i]).collect()).collect();
    let ar_solo = ar_groups
        .iter()
        .map(|g| cluster.collective_time(CollKind::AllReduce, g, shard))
        .fold(0.0, f64::max);
    // Step 3: all-gather the reduced shards back within each server.
    let ag_solo = rs_groups
        .iter()
        .map(|g| cluster.collective_time(CollKind::AllGather, g, shard))
        .fold(0.0, f64::max);
    let hier_time = rs_solo + ar_solo * m as f64 + ag_solo;
    let flat_plan = flat(cluster);
    if hier_time >= flat_plan.time {
        return flat_plan;
    }
    SyncPlan {
        steps: vec![
            SyncStep {
                kind: CollKind::ReduceScatter,
                groups: rs_groups.clone(),
                bytes: shard,
                time: rs_solo,
            },
            SyncStep {
                kind: CollKind::AllReduce,
                groups: ar_groups,
                bytes: shard,
                time: ar_solo * m as f64,
            },
            SyncStep { kind: CollKind::AllGather, groups: rs_groups, bytes: shard, time: ag_solo },
        ],
        time: hier_time,
    }
}

/// Modeled time of [`grad_sync_plan`] — the gradient-sync term of the
/// hetero planner's candidate ranking.
pub fn grad_sync_time(cluster: &Cluster, group: &[DeviceId], bytes: u64) -> f64 {
    grad_sync_plan(cluster, group, bytes).time
}

/// The paper's P2P send/recv baseline (§6.5): every consumer independently
/// fetches the full value it needs from producers — no collectives, no
/// shard reuse. For replicated consumers this ships the whole tensor to
/// each device; the traffic crosses the narrower side's NICs serially.
pub fn p2p_baseline_time(
    cluster: &Cluster,
    src_group: &[DeviceId],
    dst_group: &[DeviceId],
    total_bytes: u64,
    to: &Rvd,
) -> f64 {
    // Each consumer needs its full-value shard; value-partial consumers
    // still fetch full shards (they reconstruct partials locally).
    let per_consumer = to.shard_bytes(total_bytes);
    let total = per_consumer * dst_group.len() as u64;
    cross_time(cluster, src_group, dst_group, total)
}

/// Modeled time to hand one stage's boundary activation (`bytes` total)
/// from a `src_group`-wide stage to a `dst_group`-wide stage: both sides
/// are dim-partitioned across their group width (the hetero planner's
/// tp layout), and the cost is the RVD-synthesized conversion path, with
/// the naive gather/transfer baseline as fallback when the synthesis has
/// no route. Used by the refinement loop's RVD-aware stage-boundary moves
/// to prefer cuts whose redistribution is cheap.
pub fn stage_conversion_time(
    cluster: &Cluster,
    src_group: &[DeviceId],
    dst_group: &[DeviceId],
    bytes: u64,
) -> f64 {
    if src_group.is_empty() || dst_group.is_empty() || bytes == 0 {
        return 0.0;
    }
    let from = Rvd::new(1, 1, &[src_group.len()]);
    let to = Rvd::new(1, 1, &[dst_group.len()]);
    search_inter(cluster, src_group, dst_group, bytes, &from, &to)
        .map(|p| p.time)
        .unwrap_or_else(|| p2p_baseline_time(cluster, src_group, dst_group, bytes, &to))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cluster32() -> Cluster {
        Cluster::v100(32)
    }

    #[test]
    fn rvd_accounting() {
        let s = Rvd::new(1, 2, &[1, 2]);
        assert_eq!(s.num_devices(), 4);
        assert_eq!(s.shard_bytes(1 << 20), (1 << 20) / 2);
        assert_eq!(format!("{s}"), "R(1)V(2)D(1,2)");
    }

    #[test]
    fn fig11_allreduce_then_alltoall() {
        // Paper Fig. 11: R(1)V(2)D(1,2) -> R(2)V(1)D(2,1) over 4 devices.
        let c = cluster32();
        let group: Vec<usize> = (0..4).collect();
        let from = Rvd::new(1, 2, &[1, 2]);
        let to = Rvd::new(2, 1, &[2, 1]);
        let p = search_intra(&c, &group, 1 << 24, &from, &to).expect("path");
        // The paper's Fig. 11 illustration uses all-reduce + all-to-all; the
        // searcher may find the equivalent (and cheaper) reduce-scatter +
        // all-gather composition. Either way the value split must be
        // consumed by a reducing collective.
        assert!(
            p.steps.iter().any(|(t, _, _)| matches!(
                t.collective(),
                Some(CollKind::AllReduce) | Some(CollKind::ReduceScatter)
            )),
            "path {} lacks a reduction",
            p.describe(&from)
        );
        assert!(p.time > 0.0 && p.time.is_finite());
        assert_eq!(p.steps.last().unwrap().1, to);
        // And it can't beat the single-collective lower bound: a plain
        // reduce-scatter of the same payload.
        let rs = c.collective_time(CollKind::ReduceScatter, &group[..2], (1 << 24) / 4);
        assert!(p.time >= rs * 0.5);
    }

    #[test]
    fn stage_conversion_time_is_finite_and_layout_sensitive() {
        let c = cluster32();
        // Same-width neighbour stages on one server vs a cut that crosses
        // servers: both finite, the cross-server cut strictly costlier.
        let local = stage_conversion_time(&c, &[0, 1], &[2, 3], 1 << 24);
        let cross = stage_conversion_time(&c, &[6, 7], &[8, 9], 1 << 24);
        assert!(local > 0.0 && local.is_finite());
        assert!(cross > 0.0 && cross.is_finite());
        assert!(cross > local, "cross-server cut {cross} must beat intra {local}");
        // Degenerate inputs are free, not a panic.
        assert_eq!(stage_conversion_time(&c, &[], &[0], 1 << 20), 0.0);
        assert_eq!(stage_conversion_time(&c, &[0], &[1], 0), 0.0);
    }

    #[test]
    fn identity_path_is_empty_and_free() {
        let c = cluster32();
        let group: Vec<usize> = (0..8).collect();
        let s = Rvd::new(2, 1, &[2, 2]);
        let p = search_intra(&c, &group, 1 << 20, &s, &s).unwrap();
        assert!(p.steps.is_empty());
        assert_eq!(p.time, 0.0);
    }

    #[test]
    fn replicated_to_sharded_is_free_schunk() {
        let c = cluster32();
        let group: Vec<usize> = (0..4).collect();
        let p = search_intra(
            &c,
            &group,
            1 << 24,
            &Rvd::new(4, 1, &[1]),
            &Rvd::new(1, 1, &[4]),
        )
        .unwrap();
        assert_eq!(p.time, 0.0);
        assert_eq!(p.steps.len(), 1);
        assert!(matches!(p.steps[0].0, Transition::Schunk { .. }));
    }

    #[test]
    fn sharded_to_replicated_needs_allgather() {
        let c = cluster32();
        let group: Vec<usize> = (0..4).collect();
        let p = search_intra(
            &c,
            &group,
            1 << 24,
            &Rvd::new(1, 1, &[4]),
            &Rvd::new(4, 1, &[1]),
        )
        .unwrap();
        assert!(p.time > 0.0);
        assert!(p
            .steps
            .iter()
            .any(|(t, _, _)| matches!(t, Transition::AllGather { .. })));
    }

    #[test]
    fn fig18a_case_replicas_to_more_replicas() {
        // 4 replicas on server1 -> 8 replicas on server2: schunk +
        // RD-scatter + all-gather, cross traffic ~1 copy vs 8 for P2P.
        let c = cluster32();
        let src: Vec<usize> = (0..4).collect(); // server 0
        let dst: Vec<usize> = (8..16).collect(); // server 1
        let bytes = 1u64 << 26;
        let from = Rvd::new(4, 1, &[1]);
        let to = Rvd::new(8, 1, &[1]);
        let p = search_inter(&c, &src, &dst, bytes, &from, &to).expect("path");
        let ts: Vec<&Transition> = p.steps.iter().map(|(t, _, _)| t).collect();
        // Paper's plan: schunk → RD-scatter → all-gather. The searcher may
        // fold the schunk into the RD-scatter edge (same cross traffic, one
        // fewer step); require the scatter + gather structure and the
        // minimized cross-server volume.
        assert!(
            ts.iter().any(|t| matches!(t, Transition::RdScatter { .. })),
            "plan: {}",
            p.describe(&from)
        );
        assert!(ts.iter().any(|t| matches!(t, Transition::AllGather { .. })));
        let p2p = p2p_baseline_time(&c, &src, &dst, bytes, &to);
        assert!(p.time < p2p / 3.0, "searched {} vs p2p {p2p}", p.time);
    }

    #[test]
    fn fig18b_case_value_split_to_dim_split() {
        // 4 value-partials on server1 -> 8 dim-shards on server2:
        // reduce-scatter locally, then RD-scatter.
        let c = cluster32();
        let src: Vec<usize> = (0..4).collect();
        let dst: Vec<usize> = (8..16).collect();
        let from = Rvd::new(1, 4, &[1]);
        let to = Rvd::new(1, 1, &[8]);
        let p = search_inter(&c, &src, &dst, 1 << 26, &from, &to).expect("path");
        assert!(
            p.steps
                .iter()
                .any(|(t, _, _)| matches!(t, Transition::ReduceScatter { .. })),
            "plan: {}",
            p.describe(&from)
        );
        assert!(p
            .steps
            .iter()
            .any(|(t, _, _)| matches!(t, Transition::RdScatter { .. })));
    }

    #[test]
    fn equal_size_groups_transfer() {
        let c = cluster32();
        let src: Vec<usize> = (0..8).collect();
        let dst: Vec<usize> = (8..16).collect();
        let s = Rvd::new(1, 1, &[8]);
        let p = search_inter(&c, &src, &dst, 1 << 24, &s, &s).expect("path");
        assert!(p.time > 0.0);
    }

    #[test]
    fn shrinking_group_gather() {
        // 8 dim-shards -> 4 dim-shards on another server.
        let c = cluster32();
        let src: Vec<usize> = (0..8).collect();
        let dst: Vec<usize> = (8..12).collect();
        let p = search_inter(
            &c,
            &src,
            &dst,
            1 << 24,
            &Rvd::new(1, 1, &[8]),
            &Rvd::new(1, 1, &[4]),
        )
        .expect("path");
        assert!(p
            .steps
            .iter()
            .any(|(t, _, _)| matches!(t, Transition::RdGather { .. })));
    }

    #[test]
    fn grad_sync_flat_within_one_server() {
        let c = Cluster::v100(8);
        let p = grad_sync_plan(&c, &[0, 2, 4, 6], 1 << 26);
        assert!(!p.is_hierarchical(), "single-server sync must stay one all-reduce");
        assert_eq!(p.steps.len(), 1);
        assert_eq!(p.steps[0].kind, CollKind::AllReduce);
        assert_eq!(p.time, c.collective_time(CollKind::AllReduce, &[0, 2, 4, 6], 1 << 26));
    }

    #[test]
    fn grad_sync_decomposes_across_servers() {
        // 2 members per server over 2 servers: reduce-scatter within,
        // all-reduce across, all-gather back — and the modeled time beats
        // the flat NIC-shared all-reduce.
        let c = Cluster::v100(16);
        let group = [0usize, 4, 8, 12];
        let bytes = 1u64 << 26;
        let p = grad_sync_plan(&c, &group, bytes);
        assert!(p.is_hierarchical(), "cross-server sync must decompose");
        let kinds: Vec<CollKind> = p.steps.iter().map(|s| s.kind).collect();
        assert_eq!(kinds, vec![CollKind::ReduceScatter, CollKind::AllReduce, CollKind::AllGather]);
        // Step structure: intra-server groups then one group per shard slot.
        assert_eq!(p.steps[0].groups, vec![vec![0, 4], vec![8, 12]]);
        assert_eq!(p.steps[1].groups, vec![vec![0, 8], vec![4, 12]]);
        let flat = c.collective_time(CollKind::AllReduce, &group, bytes);
        assert!(p.time < flat, "hierarchical {} must beat flat {flat}", p.time);
        let sum: f64 = p.steps.iter().map(|s| s.time).sum();
        assert!((sum - p.time).abs() < 1e-12);
    }

    #[test]
    fn grad_sync_one_member_per_server_stays_flat() {
        let c = Cluster::v100(16);
        let p = grad_sync_plan(&c, &[0, 8], 1 << 26);
        assert!(!p.is_hierarchical(), "no local peers to reduce-scatter with");
    }

    #[test]
    fn prop_search_reaches_valid_target_states() {
        crate::util::prop::check("rvd-search", 40, |g| {
            let c = Cluster::v100(16);
            let n = *g.rng.choose(&[2usize, 4, 8]);
            let group: Vec<usize> = (0..n).collect();
            let mut factorize = |g: &mut crate::util::prop::Gen| {
                let r = g.divisor_of(n);
                let v = g.divisor_of(n / r);
                let d0 = g.divisor_of(n / r / v);
                let d1 = n / r / v / d0;
                Rvd::new(r, v, &[d0, d1])
            };
            let from = factorize(g);
            let to = factorize(g);
            match search_intra(&c, &group, 1 << 22, &from, &to) {
                None => Ok(()),
                Some(p) => {
                    let end = p
                        .steps
                        .last()
                        .map(|(_, s, _)| s.clone())
                        .unwrap_or(from.clone());
                    if end == to {
                        Ok(())
                    } else {
                        Err(format!("path ends at {end} wanted {to}"))
                    }
                }
            }
        });
    }

    #[test]
    fn prop_path_time_is_sum_of_steps() {
        crate::util::prop::check("rvd-time-sum", 30, |g| {
            let c = Cluster::v100(8);
            let group: Vec<usize> = (0..8).collect();
            let from = Rvd::new(8, 1, &[1, 1]);
            let tos = [
                Rvd::new(1, 1, &[8, 1]),
                Rvd::new(1, 1, &[1, 8]),
                Rvd::new(2, 1, &[4, 1]),
                Rvd::new(1, 1, &[2, 4]),
            ];
            let to = &tos[g.int(0, tos.len())];
            let p = search_intra(&c, &group, 1 << 20, &from, to).expect("reachable");
            let sum: f64 = p.steps.iter().map(|(_, _, dt)| dt).sum();
            if (sum - p.time).abs() > 1e-12 + 1e-9 * p.time {
                return Err(format!("sum {sum} != total {}", p.time));
            }
            Ok(())
        });
    }
}
