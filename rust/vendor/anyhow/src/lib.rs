//! Minimal offline reimplementation of the `anyhow` error-handling API
//! surface this repository uses (`Error`, `Result`, `anyhow!`, `bail!`,
//! `Context`). The real crate is not available in the offline vendor set;
//! this stand-in keeps the observable behaviour (string-y error values
//! that format with their context chain) without external dependencies.

use std::fmt;

/// A string-backed dynamic error value.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable (what `anyhow::Error::msg` does).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like the real crate: any std error converts (so `?` works), and `Error`
// itself deliberately does NOT implement `std::error::Error`, which keeps
// this blanket impl coherent.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(&e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(...)` / `.with_context(|| ...)` on fallible results.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{c}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => { $crate::Error::msg(format!($($arg)*)) };
}

/// Early-return with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::anyhow!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/file")
            .with_context(|| "reading config".to_string())?;
        Ok(s)
    }

    #[test]
    fn context_chains_into_message() {
        let e = io_fail().unwrap_err();
        assert!(format!("{e}").starts_with("reading config: "));
    }

    #[test]
    fn macros_build_errors() {
        let e = anyhow!("bad {}", 7);
        assert_eq!(format!("{e}"), "bad 7");
        fn f() -> Result<()> {
            bail!("nope")
        }
        assert_eq!(format!("{}", f().unwrap_err()), "nope");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn g() -> Result<i32> {
            let n: i32 = "xyz".parse()?;
            Ok(n)
        }
        assert!(g().is_err());
    }
}
