//! Stub of the `xla` PJRT bindings for the offline vendor set.
//!
//! Pure-data helpers (literal construction / reshape) succeed; every entry
//! point that would touch a real PJRT client returns an error, so callers
//! fail at the first device interaction with a clear message instead of at
//! link time. The `rust/src/runtime` call sites are all gated behind
//! "artifacts exist" checks, so the simulator / plan / search layers never
//! reach this code.

/// Error type matching how call sites consume it (`{e:?}` formatting).
#[derive(Debug)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: PJRT/XLA is unavailable in this build (offline `xla` stub; \
         install the real bindings to execute AOT artifacts)"
    )))
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        unavailable(&format!("parse {path}"))
    }
}

/// A computation handed to the compiler (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT client handle (stub).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("create PJRT CPU client")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("compile computation")
    }
}

/// Compiled executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("execute")
    }
}

/// Device buffer (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("fetch buffer")
    }
}

/// Host literal (stub). Construction and reshape are pure-data and succeed.
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("untuple literal")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("read literal")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let e = PjRtClient::cpu().err().expect("stub must error");
        assert!(format!("{e:?}").contains("PJRT/XLA is unavailable"));
    }

    #[test]
    fn literal_data_path_is_pure() {
        let l = Literal::vec1(&[1.0f32, 2.0]);
        assert!(l.reshape(&[2]).is_ok());
    }
}
