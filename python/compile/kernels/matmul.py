"""Layer-1 Pallas kernel: tiled matmul.

The compute hot-spot of every linear layer. Re-thought for TPU rather than
ported from CUDA (see DESIGN.md §Hardware-Adaptation):

* blocks are sized for VMEM (the ~16 MB scratchpad), not CUDA shared memory:
  default 128x512x128 tiles keep (bm*bk + bk*bn + bm*bn)*4B ~ 0.6 MB, far
  under budget, leaving headroom for double buffering;
* the inner tile is a multiple of the 128x128 MXU systolic array shape;
* the HBM<->VMEM schedule that CUDA expresses with threadblock tiling is the
  BlockSpec index maps: grid (m/bm, n/bn, k/bk) with the k axis marked
  "arbitrary" (sequential accumulation), m/n parallel.

`interpret=True` always: the CPU PJRT plugin cannot run Mosaic custom-calls;
lowering in interpret mode emits plain HLO that any backend (including the
rust PJRT CPU client) executes. Real-TPU performance is *estimated* from the
BlockSpec footprint in DESIGN.md, never from interpret-mode wall clock.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-aligned default tile sizes.
BM, BK, BN = 128, 512, 128


def _matmul_kernel(x_ref, w_ref, o_ref):
    """One (bm, bn) output tile; the k grid axis accumulates in-place.

    The output BlockSpec index map ignores `k`, so Pallas keeps the (i, j)
    tile resident in VMEM across the whole k sweep — the accumulator lives
    on-chip and HBM sees exactly one write per tile.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def matmul(x, w, bm: int = BM, bk: int = BK, bn: int = BN):
    """`x[m,k] @ w[k,n]` via the Pallas kernel (interpret mode).

    Shapes need not be tile-aligned: inputs are zero-padded up to the tile
    grid and the result sliced back (padding rows/cols contribute zeros).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm_, bk_, bn_ = min(bm, m), min(bk, k), min(bn, n)
    pad_m, pad_k, pad_n = (-m) % bm_, (-k) % bk_, (-n) % bn_
    xp = jnp.pad(x, ((0, pad_m), (0, pad_k)))
    wp = jnp.pad(w, ((0, pad_k), (0, pad_n)))
    mp, kp, np_ = m + pad_m, k + pad_k, n + pad_n
    n_k = kp // bk_
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm_, np_ // bn_, n_k),
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=True,
    )(xp, wp)
    return out[:m, :n]


def matmul_3d(x, w):
    """Batched wrapper `x[b,s,k] @ w[k,n]` flattening the leading dims."""
    b, s, k = x.shape
    return matmul(x.reshape(b * s, k), w).reshape(b, s, -1)


# ---- autodiff: backward passes are the same kernel on transposed operands.
@jax.custom_vjp
def matmul_ad(x, w):
    """Differentiable matmul: fwd and both bwd matmuls run the Pallas kernel."""
    return matmul(x, w)


def _matmul_fwd(x, w):
    return matmul(x, w), (x, w)


def _matmul_bwd(res, dy):
    x, w = res
    dx = matmul(dy, w.T)
    dw = matmul(x.T, dy)
    return dx, dw


matmul_ad.defvjp(_matmul_fwd, _matmul_bwd)


def matmul_3d_ad(x, w):
    """Differentiable batched wrapper."""
    b, s, k = x.shape
    return matmul_ad(x.reshape(b * s, k), w).reshape(b, s, -1)
