"""Pure-jnp oracles for the Pallas kernels — the correctness ground truth.

Every kernel in this package is checked against these references by
python/tests (same math, no Pallas, no tiling), including hypothesis sweeps
over shapes and dtypes. This is the CORE correctness signal of the L1
layer: if kernel == ref and ref is obviously right, the AOT artifacts built
from the kernels are right too.
"""

import jax
import jax.numpy as jnp


def matmul_ref(x, w):
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def matmul_3d_ref(x, w):
    return jnp.einsum("bsk,kn->bsn", x, w).astype(x.dtype)


def attention_ref(q, k, v, causal: bool = True):
    """q,k,v: [b, a, s, d]."""
    d = q.shape[-1]
    scores = jnp.einsum("basd,batd->bast", q, k) / jnp.sqrt(jnp.float32(d))
    if causal:
        s = q.shape[2]
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bast,batd->basd", p, v).astype(q.dtype)


def layernorm_ref(x, gamma, beta, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * gamma + beta).astype(x.dtype)
