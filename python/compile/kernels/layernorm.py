"""Layer-1 Pallas kernel: LayerNorm over the last axis.

Row-tiled: each grid cell normalizes a block of rows held in VMEM. The
reduction axis is never split (matching the rust IR, where layernorm's
hidden dim is annotated `_` = not partitionable).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BR = 256  # rows per block


def _ln_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * g_ref[...] + b_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps",))
def layernorm(x, gamma, beta, eps: float = 1e-5):
    """`x[..., h]` normalized over the last axis, scaled by gamma/beta."""
    orig_shape = x.shape
    h = orig_shape[-1]
    rows = int(x.size // h)
    xf = x.reshape(rows, h)
    br = min(BR, rows)
    pad = (-rows) % br
    xf = jnp.pad(xf, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_ln_kernel, eps=eps),
        grid=((rows + pad) // br,),
        in_specs=[
            pl.BlockSpec((br, h), lambda i: (i, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((h,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows + pad, h), x.dtype),
        interpret=True,
    )(xf, gamma, beta)
    return out[:rows].reshape(orig_shape)


# ---- autodiff: fused forward kernel + algebraic backward.
@jax.custom_vjp
def layernorm_ad(x, gamma, beta):
    return layernorm(x, gamma, beta)


def _ln_fwd(x, gamma, beta):
    return layernorm(x, gamma, beta), (x, gamma, beta)


def _ln_bwd(res, dy):
    x, gamma, beta = res
    eps = 1e-5
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    xhat = (xf - mu) * inv
    dyf = dy.astype(jnp.float32)
    dgamma = (dyf * xhat).sum(axis=tuple(range(x.ndim - 1)))
    dbeta = dyf.sum(axis=tuple(range(x.ndim - 1)))
    h = x.shape[-1]
    dxhat = dyf * gamma
    dx = inv * (dxhat - dxhat.mean(-1, keepdims=True) - xhat * (dxhat * xhat).mean(-1, keepdims=True))
    del h
    return dx.astype(x.dtype), dgamma.astype(gamma.dtype), dbeta.astype(beta.dtype)


layernorm_ad.defvjp(_ln_fwd, _ln_bwd)
