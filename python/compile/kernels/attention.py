"""Layer-1 Pallas kernel: fused multi-head self-attention (causal).

One (batch, head) grid cell computes softmax(q k^T / sqrt(d) + causal) v for
its head entirely in VMEM — the flash-attention insight (never materialize
the s x s score matrix in HBM) mapped to the TPU model: for the sequence
lengths this repo trains (<= 512), a whole head's q/k/v tiles fit VMEM
(3 * s * d * 4B ~ 0.4 MB at s=512, d=64), so the kernel holds them resident
and lets the MXU chew the two matmuls back-to-back. Longer sequences would
add a kv-block grid axis with the running-max/denominator recurrence; the
co-shard plan instead splits heads, which this grid already expresses
(the head axis IS the co-shard axis).

interpret=True as everywhere (see matmul.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, causal: bool):
    q = q_ref[0]  # [s, d]
    k = k_ref[0]
    v = v_ref[0]
    s, d = q.shape
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(d))
    if causal:
        pos = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
        kpos = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
        scores = jnp.where(kpos <= pos, scores, jnp.float32(-1e30))
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    z = jnp.sum(p, axis=-1, keepdims=True)
    o_ref[0] = jnp.dot((p / z).astype(v.dtype), v, preferred_element_type=jnp.float32).astype(
        o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("causal",))
def attention(q, k, v, causal: bool = True):
    """Fused attention. `q,k,v: [b, a, s, d]` -> `[b, a, s, d]`."""
    b, a, s, d = q.shape
    grid = (b * a,)
    flat = lambda t: t.reshape(b * a, s, d)
    out = pl.pallas_call(
        functools.partial(_attn_kernel, causal=causal),
        grid=grid,
        in_specs=[pl.BlockSpec((1, s, d), lambda i: (i, 0, 0))] * 3,
        out_specs=pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * a, s, d), q.dtype),
        interpret=True,
    )(flat(q), flat(k), flat(v))
    return out.reshape(b, a, s, d)


# ---- autodiff: forward runs the fused kernel; backward uses the algebraic
# softmax-attention gradient in plain jnp (a flash-style backward kernel is
# the natural extension; the interchange and numerics are identical).
@jax.custom_vjp
def attention_ad(q, k, v):
    return attention(q, k, v, causal=True)


def _attn_fwd(q, k, v):
    return attention(q, k, v, causal=True), (q, k, v)


def _attn_bwd(res, do):
    q, k, v = res
    d = q.shape[-1]
    s = q.shape[2]
    scores = jnp.einsum("basd,batd->bast", q, k) / jnp.sqrt(jnp.float32(d))
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    dv = jnp.einsum("bast,basd->batd", p, do)
    dp = jnp.einsum("basd,batd->bast", do, v)
    dsoft = p * (dp - jnp.sum(p * dp, axis=-1, keepdims=True))
    dsoft = jnp.where(mask, dsoft, 0.0) / jnp.sqrt(jnp.float32(d))
    dq = jnp.einsum("bast,batd->basd", dsoft, k)
    dk = jnp.einsum("bast,basd->batd", dsoft, q)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


attention_ad.defvjp(_attn_fwd, _attn_bwd)
