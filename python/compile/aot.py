"""AOT lowering: JAX/Pallas -> HLO *text* artifacts for the rust runtime.

HLO text (not a serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Run once via ``make artifacts``; Python never runs on the training path.

Outputs (artifacts/):
    fwd_loss.hlo.txt    (params..., x, y) -> (loss,)
    grad_step.hlo.txt   (params..., x, y) -> (loss, grads...)
    manifest.json       parameter ABI: ordered names/shapes, model config
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(fn, cfg, n_outputs_hint=None):
    specs = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in model.param_specs(cfg)]
    x = jax.ShapeDtypeStruct((cfg.batch, cfg.seq), jnp.int32)
    y = jax.ShapeDtypeStruct((cfg.batch, cfg.seq), jnp.int32)

    def flat_fn(*args):
        ps = list(args[: len(specs)])
        out = fn(cfg, ps, args[-2], args[-1])
        return out if isinstance(out, tuple) else (out,)

    return jax.jit(flat_fn).lower(*specs, x, y)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--vocab", type=int, default=8192)
    ap.add_argument("--hidden", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--heads", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    cfg = model.Config(
        vocab=args.vocab,
        hidden=args.hidden,
        layers=args.layers,
        heads=args.heads,
        seq=args.seq,
        batch=args.batch,
    )
    os.makedirs(args.out, exist_ok=True)

    for name, fn in [("fwd_loss", model.fwd_loss), ("grad_step", model.grad_step)]:
        lowered = lower_entry(fn, cfg)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path}: {len(text)} chars")

    manifest = {
        "config": {
            "vocab": cfg.vocab,
            "hidden": cfg.hidden,
            "layers": cfg.layers,
            "heads": cfg.heads,
            "seq": cfg.seq,
            "batch": cfg.batch,
        },
        "params": [
            {"name": n, "shape": list(s)} for n, s in model.param_specs(cfg)
        ],
        "entries": {
            "fwd_loss": {"outputs": 1},
            "grad_step": {"outputs": 1 + len(model.param_specs(cfg))},
        },
        "n_params": int(model.Config.n_params(cfg)),
    }
    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath} ({manifest['n_params']} parameters)")


if __name__ == "__main__":
    main()
