"""Layer-2: the JAX training model (decoder-only transformer) built on the
Layer-1 Pallas kernels. Build-time only — `aot.py` lowers the entry points
to HLO text once; the rust coordinator loads and executes the artifacts and
Python never appears on the training path.

Entry points exported:
* ``fwd_loss(params..., x, y) -> loss``                (eval / quickstart)
* ``grad_step(params..., x, y) -> (loss, grads...)``   (the DP hot path:
  the rust executor all-reduces the grads across simulated devices and
  applies Adam itself — L3 owns the optimizer state, matching the engine's
  weight-home model)

Parameters travel as a flat, deterministically-ordered list (see
``param_specs``); ``aot.py`` writes the ordering into
``artifacts/manifest.json`` for the rust side.
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from compile.kernels.attention import attention_ad
from compile.kernels.layernorm import layernorm_ad
from compile.kernels.matmul import matmul_ad


@dataclass(frozen=True)
class Config:
    vocab: int = 8192
    hidden: int = 256
    layers: int = 4
    heads: int = 8
    seq: int = 128
    batch: int = 8  # per-device micro-batch

    @property
    def head_dim(self):
        assert self.hidden % self.heads == 0
        return self.hidden // self.heads

    def n_params(self):
        return sum(
            int(jnp.prod(jnp.array(shape))) for _, shape in param_specs(self)
        )


def param_specs(cfg: Config):
    """Ordered (name, shape) list — the flat parameter ABI."""
    specs = [("embed", (cfg.vocab, cfg.hidden))]
    for l in range(cfg.layers):
        specs += [
            (f"h{l}.ln1g", (cfg.hidden,)),
            (f"h{l}.ln1b", (cfg.hidden,)),
            (f"h{l}.wqkv", (cfg.hidden, 3 * cfg.hidden)),
            (f"h{l}.wo", (cfg.hidden, cfg.hidden)),
            (f"h{l}.ln2g", (cfg.hidden,)),
            (f"h{l}.ln2b", (cfg.hidden,)),
            (f"h{l}.fc1", (cfg.hidden, 4 * cfg.hidden)),
            (f"h{l}.fc2", (4 * cfg.hidden, cfg.hidden)),
        ]
    specs += [("lnf_g", (cfg.hidden,)), ("lnf_b", (cfg.hidden,))]
    return specs


def init_params(cfg: Config, key):
    """Scaled-normal init matching the spec order."""
    out = []
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("ln1g", "ln2g", "lnf_g")):
            out.append(jnp.ones(shape, jnp.float32))
        elif name.endswith(("ln1b", "ln2b", "lnf_b")):
            out.append(jnp.zeros(shape, jnp.float32))
        else:
            scale = 0.02 if name == "embed" else 1.0 / float(shape[0]) ** 0.5
            out.append(scale * jax.random.normal(sub, shape, jnp.float32))
    return out


def _unflatten(cfg: Config, flat):
    return {name: t for (name, _), t in zip(param_specs(cfg), flat)}


def forward(cfg: Config, flat_params, x):
    """Token logits for `x[b, s]` (int32)."""
    p = _unflatten(cfg, flat_params)
    b, s = x.shape
    h = p["embed"][x]  # [b, s, hidden] gather
    for l in range(cfg.layers):
        n1 = layernorm_ad(h, p[f"h{l}.ln1g"], p[f"h{l}.ln1b"])
        qkv = matmul_ad(n1.reshape(b * s, cfg.hidden), p[f"h{l}.wqkv"]).reshape(
            b, s, 3, cfg.heads, cfg.head_dim
        )
        q = qkv[:, :, 0].transpose(0, 2, 1, 3)
        k = qkv[:, :, 1].transpose(0, 2, 1, 3)
        v = qkv[:, :, 2].transpose(0, 2, 1, 3)
        att = attention_ad(q, k, v)  # [b, a, s, d]
        att = att.transpose(0, 2, 1, 3).reshape(b, s, cfg.hidden)
        h = h + matmul_ad(att.reshape(b * s, cfg.hidden), p[f"h{l}.wo"]).reshape(
            b, s, cfg.hidden
        )
        n2 = layernorm_ad(h, p[f"h{l}.ln2g"], p[f"h{l}.ln2b"])
        f1 = matmul_ad(n2.reshape(b * s, cfg.hidden), p[f"h{l}.fc1"])
        f1 = jax.nn.gelu(f1)
        h = h + matmul_ad(f1, p[f"h{l}.fc2"]).reshape(b, s, cfg.hidden)
    hf = layernorm_ad(h, p["lnf_g"], p["lnf_b"])
    # Tied LM head.
    logits = matmul_ad(hf.reshape(b * s, cfg.hidden), p["embed"].T)
    return logits.reshape(b, s, cfg.vocab)


def fwd_loss(cfg: Config, flat_params, x, y):
    """Mean next-token cross-entropy of `x` against labels `y`."""
    logits = forward(cfg, flat_params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
    return nll.mean()


def grad_step(cfg: Config, flat_params, x, y):
    """(loss, grads...) — the exported training hot path."""
    loss, grads = jax.value_and_grad(lambda ps: fwd_loss(cfg, ps, x, y))(
        list(flat_params)
    )
    return (loss, *grads)
