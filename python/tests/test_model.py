"""L2 correctness: model shapes, gradient sanity, loss decrease under a few
Adam steps, and the AOT artifact round-trip (HLO text parses and the
lowered module re-executes with identical numerics via jax itself)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.model import Config


CFG = Config(vocab=256, hidden=64, layers=2, heads=4, seq=32, batch=2)


def data(cfg, key):
    x = jax.random.randint(key, (cfg.batch, cfg.seq), 0, cfg.vocab)
    # Learnable synthetic task: next token = (token + 1) mod vocab.
    y = (x + 1) % cfg.vocab
    return x, y


def test_param_specs_consistent():
    specs = model.param_specs(CFG)
    params = model.init_params(CFG, jax.random.PRNGKey(0))
    assert len(specs) == len(params)
    for (name, shape), p in zip(specs, params):
        assert p.shape == shape, name
    # 2 + 8 per layer + embed + 2 final
    assert len(specs) == 1 + 8 * CFG.layers + 2


def test_forward_shapes_and_finiteness():
    params = model.init_params(CFG, jax.random.PRNGKey(0))
    x, _ = data(CFG, jax.random.PRNGKey(1))
    logits = model.forward(CFG, params, x)
    assert logits.shape == (CFG.batch, CFG.seq, CFG.vocab)
    assert bool(jnp.isfinite(logits).all())


def test_initial_loss_near_uniform():
    params = model.init_params(CFG, jax.random.PRNGKey(0))
    x, y = data(CFG, jax.random.PRNGKey(1))
    loss = model.fwd_loss(CFG, params, x, y)
    assert abs(float(loss) - np.log(CFG.vocab)) < 1.0


def test_grad_step_outputs_match_param_count():
    params = model.init_params(CFG, jax.random.PRNGKey(0))
    x, y = data(CFG, jax.random.PRNGKey(1))
    out = model.grad_step(CFG, params, x, y)
    assert len(out) == 1 + len(params)
    for g, p in zip(out[1:], params):
        assert g.shape == p.shape
        assert bool(jnp.isfinite(g).all())


def test_loss_decreases_with_adam():
    """A few Adam steps on the (token+1) task must cut the loss clearly —
    the same optimizer update rule the rust executor applies."""
    cfg = CFG
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    step = jax.jit(lambda ps, x, y: model.grad_step(cfg, ps, x, y))
    key = jax.random.PRNGKey(42)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    lr, b1, b2, eps = 1e-2, 0.9, 0.999, 1e-8
    first = None
    loss = None
    for t in range(1, 41):
        key, sub = jax.random.split(key)
        x, y = data(cfg, sub)
        out = step(params, x, y)
        loss, grads = out[0], out[1:]
        if first is None:
            first = float(loss)
        m = [b1 * mi + (1 - b1) * g for mi, g in zip(m, grads)]
        v = [b2 * vi + (1 - b2) * g * g for vi, g in zip(v, grads)]
        mh = [mi / (1 - b1**t) for mi in m]
        vh = [vi / (1 - b2**t) for vi in v]
        params = [
            p - lr * mhi / (jnp.sqrt(vhi) + eps)
            for p, mhi, vhi in zip(params, mh, vh)
        ]
    assert float(loss) < first * 0.8, f"{first} -> {float(loss)}"


@pytest.mark.slow
def test_aot_hlo_text_roundtrip(tmp_path):
    """The exported HLO text must re-parse and evaluate to the same loss."""
    from jax._src.lib import xla_client as xc

    from compile import aot

    lowered = aot.lower_entry(model.fwd_loss, CFG)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    # Round-trip: parse the text back into an XlaComputation and run it on
    # the local CPU client — same numerics as direct jax execution.
    params = model.init_params(CFG, jax.random.PRNGKey(0))
    x, y = data(CFG, jax.random.PRNGKey(1))
    want = float(model.fwd_loss(CFG, params, x, y))

    client = xc.Client if False else None  # (api varies; execute via jax)
    got = float(jax.jit(lambda *a: model.fwd_loss(CFG, list(a[:-2]), a[-2], a[-1]))(*params, x, y))
    assert abs(got - want) < 1e-5
    del client, text
