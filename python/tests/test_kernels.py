"""L1 correctness: every Pallas kernel against its pure-jnp oracle,
including hypothesis sweeps over shapes (and the f32/bf16 dtypes the rust
IR supports). This is the core correctness signal for the AOT artifacts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import attention, attention_ad
from compile.kernels.layernorm import layernorm, layernorm_ad
from compile.kernels.matmul import matmul, matmul_3d, matmul_ad

KEY = jax.random.PRNGKey(0)


def rand(shape, dtype=jnp.float32, key=KEY):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ---------- matmul ----------


@pytest.mark.parametrize(
    "m,k,n",
    [(1, 1, 1), (8, 16, 8), (128, 512, 128), (100, 300, 70), (129, 513, 127)],
)
def test_matmul_matches_ref(m, k, n):
    x, w = rand((m, k)), rand((k, n), key=jax.random.PRNGKey(1))
    np.testing.assert_allclose(matmul(x, w), ref.matmul_ref(x, w), rtol=2e-5, atol=2e-5)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 200),
    k=st.integers(1, 300),
    n=st.integers(1, 150),
    bm=st.sampled_from([32, 64, 128]),
    bk=st.sampled_from([64, 128, 512]),
    bn=st.sampled_from([32, 128]),
)
def test_matmul_hypothesis_shapes_and_tiles(m, k, n, bm, bk, bn):
    """Any shape against any tile config — padding/slicing must be exact."""
    x = rand((m, k))
    w = rand((k, n), key=jax.random.PRNGKey(2))
    got = matmul(x, w, bm=bm, bk=bk, bn=bn)
    np.testing.assert_allclose(got, ref.matmul_ref(x, w), rtol=3e-5, atol=3e-5)


def test_matmul_bf16():
    x = rand((64, 64), jnp.bfloat16)
    w = rand((64, 32), jnp.bfloat16, key=jax.random.PRNGKey(3))
    got = matmul(x, w).astype(jnp.float32)
    want = ref.matmul_ref(x, w).astype(jnp.float32)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


def test_matmul_3d():
    x = rand((2, 17, 48))
    w = rand((48, 24), key=jax.random.PRNGKey(4))
    np.testing.assert_allclose(
        matmul_3d(x, w), ref.matmul_3d_ref(x, w), rtol=2e-5, atol=2e-5
    )


def test_matmul_grad_matches_ref_grad():
    x = rand((16, 32))
    w = rand((32, 8), key=jax.random.PRNGKey(5))
    g1 = jax.grad(lambda a, b: matmul_ad(a, b).sum(), argnums=(0, 1))(x, w)
    g2 = jax.grad(lambda a, b: ref.matmul_ref(a, b).sum(), argnums=(0, 1))(x, w)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


# ---------- attention ----------


@pytest.mark.parametrize("b,a,s,d", [(1, 1, 4, 8), (2, 4, 64, 16), (1, 8, 128, 32)])
def test_attention_matches_ref(b, a, s, d):
    q, k, v = (rand((b, a, s, d), key=jax.random.PRNGKey(i)) for i in range(3))
    np.testing.assert_allclose(
        attention(q, k, v), ref.attention_ref(q, k, v), rtol=2e-5, atol=2e-5
    )


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 3),
    a=st.sampled_from([1, 2, 4]),
    s=st.sampled_from([2, 8, 32, 96]),
    d=st.sampled_from([4, 16, 64]),
)
def test_attention_hypothesis(b, a, s, d):
    q, k, v = (rand((b, a, s, d), key=jax.random.PRNGKey(i + 7)) for i in range(3))
    np.testing.assert_allclose(
        attention(q, k, v), ref.attention_ref(q, k, v), rtol=3e-5, atol=3e-5
    )


def test_attention_is_causal():
    """Perturbing a future token must not change earlier outputs."""
    q, k, v = (rand((1, 1, 16, 8), key=jax.random.PRNGKey(i)) for i in range(3))
    base = attention(q, k, v)
    k2 = k.at[:, :, -1].add(100.0)
    v2 = v.at[:, :, -1].add(100.0)
    pert = attention(q, k2, v2)
    np.testing.assert_allclose(base[:, :, :-1], pert[:, :, :-1], rtol=1e-5, atol=1e-5)
    assert not np.allclose(base[:, :, -1], pert[:, :, -1])


def test_attention_grads():
    q, k, v = (rand((1, 2, 16, 8), key=jax.random.PRNGKey(i)) for i in range(3))
    g1 = jax.grad(lambda q, k, v: attention_ad(q, k, v).sum(), argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda q, k, v: ref.attention_ref(q, k, v).sum(), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-4)


# ---------- layernorm ----------


@settings(max_examples=20, deadline=None)
@given(
    rows=st.integers(1, 300),
    h=st.sampled_from([8, 48, 256]),
)
def test_layernorm_hypothesis(rows, h):
    x = rand((rows, h))
    g = rand((h,), key=jax.random.PRNGKey(11)) + 1.0
    b = rand((h,), key=jax.random.PRNGKey(12))
    np.testing.assert_allclose(
        layernorm(x, g, b), ref.layernorm_ref(x, g, b), rtol=2e-5, atol=2e-5
    )


def test_layernorm_3d_and_grads():
    x = rand((3, 5, 32))
    g = jnp.ones(32) * 1.5
    b = jnp.zeros(32) + 0.2
    np.testing.assert_allclose(
        layernorm(x, g, b), ref.layernorm_ref(x, g, b), rtol=2e-5, atol=2e-5
    )
    d1 = jax.grad(lambda x: layernorm_ad(x, g, b).sum())(x)
    d2 = jax.grad(lambda x: ref.layernorm_ref(x, g, b).sum())(x)
    np.testing.assert_allclose(d1, d2, rtol=1e-3, atol=1e-4)


def test_layernorm_output_stats():
    """Unit gamma, zero beta -> per-row mean ~0, var ~1."""
    x = rand((64, 128)) * 7.0 + 3.0
    y = layernorm(x, jnp.ones(128), jnp.zeros(128))
    np.testing.assert_allclose(np.asarray(y.mean(-1)), 0.0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y.var(-1)), 1.0, atol=1e-3)
